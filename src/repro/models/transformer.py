"""Decoder stacks for every assigned architecture family.

Design notes:
  * Layers are stacked along a leading axis and executed with ``lax.scan``
    so HLO size / compile time stay O(1 layer) even for the 61-layer 1T MoE
    at 512 devices.  Heterogeneous stacks (RecurrentGemma's rec/rec/attn
    pattern, MoE dense prefixes) scan over "superblocks" of one pattern
    repeat, with the non-multiple remainder unrolled.
  * KV caches are ring buffers of capacity ``min(window, max_len)`` so
    sliding-window / local-attention archs keep bounded decode state
    (long_500k eligibility).  ``slot_pos`` carries the absolute position of
    each slot; masking in the attention ops uses positions, so ring
    non-monotonicity is harmless.
  * All functions are functional; ``mode`` is one of train|prefill|decode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import chunked_prefill_attention as cpa_kernel
from repro.kernels import paged_decode_attention as pfd_kernel
from repro.kernels import ragged_chunked_prefill as rcp_kernel
from repro.kvcache import paged as paged_lib
from repro.sharding import context as shctx

from . import layers, moe as moe_lib, rglru, ssm
from .layers import rms_norm

Array = jax.Array


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------


def init_attn_mlp_block(key, cfg, dtype, *, use_moe=False, cross=False):
    ks = jax.random.split(key, 5)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": layers.init_attention(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                   cfg.mlp_act, dtype)
    if cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = layers.init_attention(ks[2], cfg, dtype)
    return p


def init_ssm_block(key, cfg, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "mixer": ssm.init_mamba2(key, cfg, dtype)}


def init_rec_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "rec": rglru.init_rglru_block(ks[0], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                   cfg.mlp_act, dtype)}


# ---------------------------------------------------------------------------
# KV-cache ring buffer helpers
# ---------------------------------------------------------------------------


def kv_cache_capacity(cfg, max_len: int, window: Optional[int]) -> int:
    return min(window, max_len) if window else max_len


def empty_slot_pos(capacity: int) -> Array:
    return jnp.full((capacity,), 2**30, jnp.int32)


def prefill_write_kv(cache_k, cache_v, k, v, slot_pos_template=None):
    """Write a freshly prefilled sequence of length S into a ring cache.

    cache_k/v: (B, W, KV, D); k/v: (B, S, KV, D).  Prefill always starts at
    position 0, so slots are positions mod W.  Returns new caches + the
    slot->position map (W,).
    """
    Wc = cache_k.shape[1]
    S = k.shape[1]
    if S >= Wc:
        tail_k, tail_v = k[:, S - Wc:], v[:, S - Wc:]
        shift = S % Wc
        new_k = jnp.roll(tail_k, shift, axis=1).astype(cache_k.dtype)
        new_v = jnp.roll(tail_v, shift, axis=1).astype(cache_v.dtype)
        slot_pos = jnp.roll(jnp.arange(S - Wc, S, dtype=jnp.int32), shift)
    else:
        new_k = lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), 0, axis=1)
        new_v = lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), 0, axis=1)
        slot_pos = empty_slot_pos(Wc).at[:S].set(
            jnp.arange(S, dtype=jnp.int32))
    return new_k, new_v, slot_pos


def prefill_slot_pos(capacity: int, seq_len: int) -> Array:
    """Slot -> absolute-position map after prefilling ``seq_len`` tokens."""
    if seq_len >= capacity:
        shift = seq_len % capacity
        return jnp.roll(
            jnp.arange(seq_len - capacity, seq_len, dtype=jnp.int32), shift)
    return empty_slot_pos(capacity).at[:seq_len].set(
        jnp.arange(seq_len, dtype=jnp.int32))


def decode_write_kv(cache_k, cache_v, k, v, pos):
    """Write one token (B, 1, KV, D) at ring slot pos % W.

    ``pos`` is either a scalar (batch-mode decode: every row sits at the
    same position) or a (B,) vector (continuous batching: every slot
    tracks an independent sequence), in which case each row scatters at
    its own ring slot."""
    Wc = cache_k.shape[1]
    idx = (pos % Wc).astype(jnp.int32)
    if idx.ndim:
        rows = jnp.arange(cache_k.shape[0])
        new_k = cache_k.at[rows, idx].set(k[:, 0].astype(cache_k.dtype))
        new_v = cache_v.at[rows, idx].set(v[:, 0].astype(cache_v.dtype))
        return new_k, new_v
    new_k = lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), idx, axis=1)
    new_v = lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), idx, axis=1)
    return new_k, new_v


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _attn_seq(p, x, positions, cfg, window, kv_len_hint=None):
    """Full-sequence self attention (train / prefill compute)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attention_qkv(p["attn"], h, positions, cfg.rope_theta)
    S = x.shape[1]
    policy = shctx.current()
    q_chunk = 1024
    if policy is not None and policy.use_seq_attention(S, cfg.num_heads):
        # sequence-sharded attention (heads don't divide the model axis):
        # q stays sharded on its seq dim — no q-chunk scan, so the
        # sharded dim is never scanned over; kv still streams in chunks.
        q_chunk = S
    if window is not None and window < S:
        # (windowed attention keeps its own chunking: its per-chunk kv
        # span is what makes it sub-quadratic; no assigned arch combines
        # SWA with a non-divisible head count)
        attn = layers.windowed_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            window=window)
    else:
        attn = layers.chunked_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=True, window=window, q_chunk=q_chunk)
    return x + layers.attention_out(p["attn"], attn), k, v


def _attn_decode(p, x, cache_k, cache_v, pos, slot_pos, cfg, window):
    """One-token self attention against the ring cache.

    pos is a scalar with slot_pos (W,) in batch mode, or (B,) with
    slot_pos (B, W) in per-slot (continuous-batching) mode — every batch
    row then advances an independent sequence.
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attention_qkv(p["attn"], h, pos[..., None],
                                   cfg.rope_theta)
    new_k, new_v = decode_write_kv(cache_k, cache_v, k, v, pos)
    Wc = cache_k.shape[1]
    if pos.ndim:
        rows = jnp.arange(slot_pos.shape[0])
        new_slot_pos = slot_pos.at[rows, pos % Wc].set(pos)
    else:
        new_slot_pos = slot_pos.at[pos % Wc].set(pos)
    valid = jnp.minimum(pos + 1, Wc)
    attn = layers.decode_attention(
        q, new_k, new_v, q_position=pos, kv_positions=new_slot_pos,
        valid_len=valid, window=window)
    return (x + layers.attention_out(p["attn"], attn), new_k, new_v,
            new_slot_pos)


def _attn_decode_paged(p, x, pages_k, pages_v, pos, tables, cfg,
                       use_pallas: bool = False):
    """One-token self attention against a paged (block-table) KV cache.

    pos: (B,) per-slot logical positions; tables: (B, nb) i32 physical
    page ids; pages_k/v: (N, bs, KV, D).  The new token scatters into
    page ``tables[s, pos[s]//bs]`` and attention runs over the paged
    pool — positions 0..pos are bit-identical to the contiguous slot
    cache's layout (absolute-position order, masked tail), so the paged
    engine matches the contiguous engine token for token.

    ``use_pallas`` routes the attention through the Pallas
    ``paged_decode_attention`` kernel, which streams pages through VMEM
    via scalar-prefetch block-table indirection (the production TPU
    path); the default jnp path gathers a transient contiguous view —
    exact, but O(slots * max_len) scratch per layer.  On non-TPU
    backends the kernel body runs in interpret mode (correct, slow) —
    the engine auto-selects per backend (``generate.make_paged_decode_fn``).
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attention_qkv(p["attn"], h, pos[..., None],
                                   cfg.rope_theta)
    new_k = paged_lib.scatter_token(pages_k, k[:, 0], tables, pos)
    new_v = paged_lib.scatter_token(pages_v, v[:, 0], tables, pos)
    if use_pallas:
        attn = pfd_kernel.paged_flash_decode_attention(
            q[:, 0], new_k, new_v, tables, pos + 1,
            interpret=jax.default_backend() != "tpu")[:, None]
    else:
        k_seq = paged_lib.gather_tokens(new_k, tables)  # (B, nb*bs, KV, D)
        v_seq = paged_lib.gather_tokens(new_v, tables)
        L = k_seq.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32),
                                  (x.shape[0], L))
        attn = layers.decode_attention(
            q, k_seq, v_seq, q_position=pos, kv_positions=kv_pos,
            valid_len=pos + 1, window=None)
    return x + layers.attention_out(p["attn"], attn), new_k, new_v


def _attn_chunk_paged(p, x, pages_k, pages_v, positions, table_row, cfg,
                      use_pallas: bool = False):
    """Chunked-prefill self attention for ONE sequence (batch dim 1).

    x: (1, T, D) the in-flight chunk; positions: (T,) its absolute
    positions ``ctx_len .. ctx_len + T - 1`` (traced); table_row: (nb,)
    i32 the sequence's block table.  The chunk's K/V scatter into the
    page pool at those positions FIRST, then the queries attend over
    the gathered logical view — full over the already-written prefix,
    causal within the chunk.  The jnp path runs the same
    ``layers.chunked_attention`` recipe as the stall prefill
    (``_attn_seq``), so per-position outputs — and therefore the KV the
    chunk writes and the final-chunk logits — match the stall-admission
    engine token for token; ``use_pallas`` routes through the
    ``chunked_prefill_attention`` kernel (block-table scalar-prefetch,
    no contiguous view materialized).
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attention_qkv(p["attn"], h, positions[None, :],
                                   cfg.rope_theta)
    new_k = paged_lib.scatter_chunk(pages_k, k[0], table_row, positions[0])
    new_v = paged_lib.scatter_chunk(pages_v, v[0], table_row, positions[0])
    if use_pallas:
        attn = cpa_kernel.chunked_prefill_attention(
            q, new_k, new_v, table_row[None, :], positions[:1],
            interpret=jax.default_backend() != "tpu")
    else:
        k_seq = paged_lib.gather_tokens(new_k, table_row[None, :])
        v_seq = paged_lib.gather_tokens(new_v, table_row[None, :])
        L = k_seq.shape[1]
        attn = layers.chunked_attention(
            q, k_seq, v_seq, q_positions=positions,
            kv_positions=jnp.arange(L, dtype=jnp.int32), causal=True)
    return x + layers.attention_out(p["attn"], attn), new_k, new_v


def _attn_chunks_paged(p, x, pages_k, pages_v, ctx, cfg):
    """Fused ragged chunked-prefill attention: EVERY scheduled chunk of
    one engine iteration in one pass (batch dim 1, packed tokens).

    x: (1, TT, D) the PACKED token stream — chunk ``c`` owns rows
    ``q_off[c] .. q_off[c] + len[c] - 1``; ctx carries the per-chunk
    metadata (``meta`` rows ``[slot, ctx_len, chunk_len, q_offset]``,
    per-chunk block tables, per-token chunk ids / positions / validity
    and the static padded chunk length).  All chunks' K/V scatter into
    the page pools in one pass and each chunk attends full over its
    already-written prefix, causal within the chunk.

    The jnp path runs the exact per-chunk ``layers.chunked_attention``
    recipe over the gathered view (a static Python loop over the
    padded chunk count — ONE traced executable, so per-position
    numerics and therefore greedy output are bit-identical to the
    sequential per-chunk path and to stall admission); ``use_pallas``
    routes through the fused ``ragged_chunked_prefill`` kernel, whose
    in-kernel scatter (aliased page outputs) replaces the separate
    ``scatter_packed`` pass entirely.
    """
    positions = ctx["positions"]             # (TT,) absolute positions
    token_chunk = ctx["token_chunk"]         # (TT,) row -> chunk id
    local = ctx["local"]                     # (TT,) row within its chunk
    valid = ctx["valid"]                     # (TT,) False = padding row
    meta = ctx["meta"]                       # (C, 4) i32
    tables = ctx["table_rows"]               # (C, nb) i32
    Tp = ctx["chunk_pad"]                    # static padded chunk length
    C = meta.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attention_qkv(p["attn"], h, positions[None, :],
                                   cfg.rope_theta)
    TT = x.shape[1]
    # per-chunk padded views of the packed stream (row t of chunk c is
    # packed row q_off[c] + t; rows past chunk_len are padding)
    qidx = jnp.clip(meta[:, 3][:, None]
                    + jnp.arange(Tp, dtype=jnp.int32)[None, :], 0, TT - 1)
    if ctx.get("use_pallas", False):
        # chunk K/V are pre-cast to the page dtype so the kernel's
        # in-chunk phase matches the post-scatter page contents the
        # gathered jnp path reads
        qv = jnp.take(q[0], qidx.reshape(-1), axis=0).reshape(
            (C, Tp) + q.shape[2:])
        knv = jnp.take(k[0].astype(pages_k.dtype), qidx.reshape(-1),
                       axis=0).reshape((C, Tp) + k.shape[2:])
        vnv = jnp.take(v[0].astype(pages_v.dtype), qidx.reshape(-1),
                       axis=0).reshape((C, Tp) + v.shape[2:])
        av, new_k, new_v = rcp_kernel.ragged_chunked_prefill(
            qv, knv, vnv, pages_k, pages_v, tables, meta,
            interpret=jax.default_backend() != "tpu")
    else:
        new_k = paged_lib.scatter_packed(pages_k, k[0], tables,
                                         token_chunk, positions, valid)
        new_v = paged_lib.scatter_packed(pages_v, v[0], tables,
                                         token_chunk, positions, valid)
        k_seq = paged_lib.gather_tokens(new_k, tables)  # (C, nb*bs, KV, D)
        v_seq = paged_lib.gather_tokens(new_v, tables)
        L = k_seq.shape[1]
        outs = []
        for c in range(C):                   # static: C is a shape
            qc = jnp.take(q, qidx[c], axis=1)           # (1, Tp, H, D)
            outs.append(layers.chunked_attention(
                qc, k_seq[c:c + 1], v_seq[c:c + 1],
                q_positions=meta[c, 1] + jnp.arange(Tp, dtype=jnp.int32),
                kv_positions=jnp.arange(L, dtype=jnp.int32),
                causal=True)[0])
        av = jnp.stack(outs)                 # (C, Tp, H, D)
    # repack: packed row j is row local[j] of chunk token_chunk[j]
    attn = av[token_chunk, jnp.clip(local, 0, Tp - 1)][None]
    return x + layers.attention_out(p["attn"], attn), new_k, new_v


def _project_enc_kv(p, enc_out):
    """Per-layer K/V projections of the shared encoder memory (no rope)."""
    enc_k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
    enc_v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
    return enc_k, enc_v


def _cross_attn(p, x, enc_k, enc_v, cfg):
    """Cross attention against the (already projected) encoder memory."""
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["xattn"]["wq"])
    Te = enc_k.shape[1]
    pos_q = jnp.full((x.shape[1],), Te, jnp.int32)  # attend to everything
    attn = layers.chunked_attention(
        q, enc_k, enc_v, q_positions=pos_q,
        kv_positions=jnp.arange(Te), causal=False)
    return x + jnp.einsum("bshk,hkd->bsd", attn, p["xattn"]["wo"])


def _mlp_part(p, x, cfg):
    return x + layers.apply_mlp(p["mlp"], rms_norm(x, p["ln2"],
                                                   cfg.norm_eps), cfg.mlp_act)


def _moe_part(p, x, cfg, capacity_factor=None):
    y, aux = moe_lib.apply_moe(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                               cfg)
    return x + y, aux


ZERO_AUX = {"moe_aux_loss": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}


def apply_block_seq(kind, p, x, ctx, cfg, cache=None):
    """Full-sequence application of one block.

    ctx: dict(positions, enc_k, enc_v).  cache: per-layer cache pytree or
    None (train).  Returns (x, new_cache, aux).
    """
    positions = ctx["positions"]
    aux = ZERO_AUX
    # "seq" resolves to "model" only under the seq-parallel policy flag —
    # the residual stream (and thus every saved layer input under remat)
    # is then sequence-sharded between blocks (16x less live memory).
    x = shctx.constrain(x, ("batch", "seq", None))
    if kind in ("dense", "moe", "cross"):
        window = cfg.window if kind != "attn_local" else cfg.local_window
        x, k, v = _attn_seq(p, x, positions, cfg, window)
        new_cache = None
        if cache is not None:
            nk, nv, _ = prefill_write_kv(cache["k"], cache["v"], k, v)
            new_cache = dict(cache, k=nk, v=nv)
        if kind == "cross":
            enc_k, enc_v = _project_enc_kv(p, ctx["enc_out"])
            x = _cross_attn(p, x, enc_k, enc_v, cfg)
            if new_cache is not None:
                new_cache["enc_k"] = enc_k.astype(new_cache["enc_k"].dtype)
                new_cache["enc_v"] = enc_v.astype(new_cache["enc_v"].dtype)
        if kind == "moe":
            x, aux = _moe_part(p, x, cfg)
        else:
            x = _mlp_part(p, x, cfg)
        return x, new_cache, aux
    if kind == "attn_local":
        x, k, v = _attn_seq(p, x, positions, cfg, cfg.local_window)
        new_cache = None
        if cache is not None:
            nk, nv, _ = prefill_write_kv(cache["k"], cache["v"], k, v)
            new_cache = dict(cache, k=nk, v=nv)
        return _mlp_part(p, x, cfg), new_cache, aux
    if kind == "ssm":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y, new_state = ssm.apply_mamba2(p["mixer"], h, cfg,
                                        None if cache is None else cache)
        return x + y, new_state, aux
    if kind == "rec":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_state = rglru.apply_recurrent_block(
            p["rec"], h, cfg, None if cache is None else cache)
        return _mlp_part(p, x + y, cfg), new_state, aux
    raise ValueError(kind)


def apply_block_chunk(kind, p, x, ctx, cfg, cache):
    """Chunked-prefill application of one block against a paged cache.

    ctx: dict(positions (T,) traced absolute positions, table_row (nb,)
    i32, use_pallas bool).  Only the paged-eligible kinds apply
    (``paged_supported`` gates the engine to dense/moe stacks).
    """
    aux = ZERO_AUX
    x = shctx.constrain(x, ("batch", None, None))
    if kind in ("dense", "moe"):
        x, nk, nv = _attn_chunk_paged(
            p, x, cache["k"], cache["v"], ctx["positions"],
            ctx["table_row"], cfg, ctx.get("use_pallas", False))
        if kind == "moe":
            x, aux = _moe_part(p, x, cfg)
        else:
            x = _mlp_part(p, x, cfg)
        return x, dict(cache, k=nk, v=nv), aux
    raise NotImplementedError(
        f"chunked prefill requires a paged-eligible stack (got {kind!r})")


def apply_block_chunks(kind, p, x, ctx, cfg, cache):
    """Fused ragged chunked-prefill application of one block: the whole
    packed multi-chunk batch against the paged cache in one pass
    (``_attn_chunks_paged``).  Same kind gating as the per-chunk mode
    (``paged_supported`` restricts the engine to dense/moe stacks).
    """
    aux = ZERO_AUX
    x = shctx.constrain(x, ("batch", None, None))
    if kind in ("dense", "moe"):
        x, nk, nv = _attn_chunks_paged(p, x, cache["k"], cache["v"],
                                       ctx, cfg)
        if kind == "moe":
            x, aux = _moe_part(p, x, cfg)
        else:
            x = _mlp_part(p, x, cfg)
        return x, dict(cache, k=nk, v=nv), aux
    raise NotImplementedError(
        f"chunked prefill requires a paged-eligible stack (got {kind!r})")


def apply_block_decode(kind, p, x, ctx, cfg, cache):
    pos = ctx["pos"]
    tables = ctx.get("tables")         # paged decode: (B, nb) block table
    aux = ZERO_AUX
    x = shctx.constrain(x, ("batch", None, None))
    if kind in ("dense", "moe", "cross"):
        if tables is not None:
            x, nk, nv = _attn_decode_paged(p, x, cache["k"], cache["v"],
                                           pos, tables, cfg,
                                           ctx.get("use_pallas", False))
        else:
            x, nk, nv, _ = _attn_decode(p, x, cache["k"], cache["v"], pos,
                                        ctx["slot_pos"], cfg, cfg.window)
        if kind == "cross":
            x = _cross_attn(p, x, cache["enc_k"], cache["enc_v"], cfg)
        if kind == "moe":
            x, aux = _moe_part(p, x, cfg)
        else:
            x = _mlp_part(p, x, cfg)
        return x, dict(cache, k=nk, v=nv), aux
    if kind == "attn_local":
        x, nk, nv, _ = _attn_decode(p, x, cache["k"], cache["v"], pos,
                                    ctx["slot_pos"], cfg, cfg.local_window)
        return _mlp_part(p, x, cfg), dict(cache, k=nk, v=nv), aux
    if kind == "ssm":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y, new_state = ssm.decode_mamba2(p["mixer"], h, cfg, cache)
        return x + y, new_state, aux
    if kind == "rec":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_state = rglru.decode_recurrent_block(p["rec"], h, cfg, cache)
        return _mlp_part(x=x + y, p=p, cfg=cfg), new_state, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack structure: pattern of block kinds -> scanned superblocks + remainder
# ---------------------------------------------------------------------------


def stack_pattern(cfg) -> tuple[tuple[str, ...], int, tuple[str, ...],
                                tuple[str, ...]]:
    """Returns (pattern, n_repeats, prefix_kinds, tail_kinds)."""
    if cfg.family == "moe":
        prefix = ("dense",) * cfg.num_dense_layers
        n = cfg.num_layers - cfg.num_dense_layers
        return ("moe",), n, prefix, ()
    if cfg.family == "ssm":
        return ("ssm",), cfg.num_layers, (), ()
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn_local")
        n = cfg.num_layers // len(pat)
        rem = cfg.num_layers - n * len(pat)
        return pat, n, (), pat[:rem]
    # dense / vlm / encdec decoder
    kind = "cross" if cfg.family == "encdec" else "dense"
    return (kind,), cfg.num_layers, (), ()


def _init_kind(kind, key, cfg, dtype):
    if kind == "dense":
        return init_attn_mlp_block(key, cfg, dtype)
    if kind == "moe":
        return init_attn_mlp_block(key, cfg, dtype, use_moe=True)
    if kind == "cross":
        return init_attn_mlp_block(key, cfg, dtype, cross=True)
    if kind == "attn_local":
        return init_attn_mlp_block(key, cfg, dtype)
    if kind == "ssm":
        return init_ssm_block(key, cfg, dtype)
    if kind == "rec":
        return init_rec_block(key, cfg, dtype)
    raise ValueError(kind)


def init_stack(key, cfg, dtype) -> dict:
    pat, n, prefix, tail = stack_pattern(cfg)
    out = {}
    kp, ks, kt = jax.random.split(key, 3)
    for i, kind in enumerate(prefix):
        out[f"prefix{i}"] = _init_kind(kind, jax.random.fold_in(kp, i),
                                       cfg, dtype)
    if n > 0:
        for s, kind in enumerate(pat):
            keys = jax.random.split(jax.random.fold_in(ks, s), n)
            out[f"scan{s}"] = jax.vmap(
                lambda k: _init_kind(kind, k, cfg, dtype))(keys)
    for i, kind in enumerate(tail):
        out[f"tail{i}"] = _init_kind(kind, jax.random.fold_in(kt, i),
                                     cfg, dtype)
    return out


def _sum_aux(a, b):
    return {k: a[k] + b[k] for k in a}


def apply_stack(params: dict, x: Array, ctx: dict, cfg, cache=None,
                mode: str = "train", remat: bool = False):
    """Run the whole block stack. Returns (x, new_cache, aux)."""
    pat, n, prefix, tail = stack_pattern(cfg)
    aux = dict(ZERO_AUX)
    new_cache = {} if cache is not None else None
    apply_fn = {"decode": apply_block_decode,
                "chunk": apply_block_chunk,
                "chunks": apply_block_chunks}.get(mode, apply_block_seq)

    for i, kind in enumerate(prefix):
        c = None if cache is None else cache[f"prefix{i}"]
        x, nc, a = apply_fn(kind, params[f"prefix{i}"], x, ctx, cfg, c)
        aux = _sum_aux(aux, a)
        if new_cache is not None:
            new_cache[f"prefix{i}"] = nc

    if n > 0:
        def superblock(x, inp):
            ps, cs = inp
            auxes = dict(ZERO_AUX)
            ncs = [None] * len(pat)
            for s, kind in enumerate(pat):
                c = None if cs is None else cs[s]
                x, nc, a = apply_fn(kind, ps[s], x, ctx, cfg, c)
                auxes = _sum_aux(auxes, a)
                ncs[s] = nc
            if cs is None:
                return x, auxes
            return x, (tuple(ncs), auxes)

        body = jax.checkpoint(superblock) if (remat and mode == "train") \
            else superblock
        p_stacked = tuple(params[f"scan{s}"] for s in range(len(pat)))
        if cache is None:
            x, auxes = lax.scan(body, x, (p_stacked, None))
        else:
            c_stacked = tuple(cache[f"scan{s}"] for s in range(len(pat)))
            x, (nc_stacked, auxes) = lax.scan(body, x,
                                              (p_stacked, c_stacked))
            for s in range(len(pat)):
                new_cache[f"scan{s}"] = nc_stacked[s]
        aux = _sum_aux(aux, jax.tree.map(jnp.sum, auxes))

    for i, kind in enumerate(tail):
        c = None if cache is None else cache[f"tail{i}"]
        x, nc, a = apply_fn(kind, params[f"tail{i}"], x, ctx, cfg, c)
        aux = _sum_aux(aux, a)
        if new_cache is not None:
            new_cache[f"tail{i}"] = nc

    return x, new_cache, aux


# ---------------------------------------------------------------------------
# cache construction (zeros for the real engine; specs for the dry-run)
# ---------------------------------------------------------------------------


def _layer_cache_zeros(kind, cfg, batch, max_len, dtype):
    if kind in ("dense", "moe", "cross", "attn_local"):
        window = cfg.local_window if kind == "attn_local" else cfg.window
        cap = kv_cache_capacity(cfg, max_len, window)
        c = {"k": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim),
                            dtype),
             "v": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim),
                            dtype)}
        if kind == "cross":
            c["enc_k"] = jnp.zeros(
                (batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim),
                dtype)
            c["enc_v"] = jnp.zeros_like(c["enc_k"])
        return c
    if kind == "ssm":
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim),
                                  dtype),
                "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)}
    if kind == "rec":
        lw = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, lw),
                                  dtype),
                "h": jnp.zeros((batch, lw), jnp.float32)}
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    pat, n, prefix, tail = stack_pattern(cfg)
    cache = {}
    for i, kind in enumerate(prefix):
        cache[f"prefix{i}"] = _layer_cache_zeros(kind, cfg, batch, max_len,
                                                 dtype)
    if n > 0:
        for s, kind in enumerate(pat):
            one = _layer_cache_zeros(kind, cfg, batch, max_len, dtype)
            cache[f"scan{s}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)
    for i, kind in enumerate(tail):
        cache[f"tail{i}"] = _layer_cache_zeros(kind, cfg, batch, max_len,
                                               dtype)
    # global scalars
    cap = kv_cache_capacity(cfg, max_len,
                            cfg.window or (cfg.local_window
                                           if cfg.family == "hybrid"
                                           else None))
    cache["pos"] = jnp.zeros((), jnp.int32)
    cache["slot_pos"] = empty_slot_pos(cap if cfg.family != "ssm" else 1)
    return cache


# ---------------------------------------------------------------------------
# continuous batching: per-slot cache (independent sequence per batch row)
# ---------------------------------------------------------------------------


def init_slot_cache(cfg, num_slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    """A decode cache whose ``pos``/``slot_pos`` are tracked PER SLOT:
    pos (C,) i32 and slot_pos (C, W) i32, so each batch row runs an
    independent sequence (admitted/evicted at any decode step)."""
    cache = init_cache(cfg, num_slots, max_len, dtype)
    cap = cache["slot_pos"].shape[0]
    cache["pos"] = jnp.zeros((num_slots,), jnp.int32)
    cache["slot_pos"] = jnp.broadcast_to(
        empty_slot_pos(cap), (num_slots, cap)).copy()
    return cache


# ---------------------------------------------------------------------------
# paged KV cache (block-table indirection; see repro.kvcache)
# ---------------------------------------------------------------------------


def paged_supported(cfg) -> tuple[bool, str]:
    """Whether the paged KV path applies to this config.

    Paging stores tokens by absolute position, so it requires full
    (non-windowed) attention layers and no recurrent/conv state; the
    sliding-window ring, SSM and RG-LRU states are O(window)/O(1)
    already — paging them buys nothing.
    """
    if cfg.family not in ("dense", "moe"):
        return False, (f"family {cfg.family!r} carries recurrent/cross "
                       "state the paged cache does not cover")
    if cfg.window is not None:
        return False, "sliding-window ring cache is already bounded"
    if cfg.frontend:
        return False, "multimodal prefix tokens not paged yet"
    return True, ""


def init_paged_cache(cfg, num_slots: int, num_blocks: int,
                     block_size: int, dtype=jnp.bfloat16) -> dict:
    """A paged decode cache: per-layer K/V page pools shared by ALL
    slots (``(num_blocks, block_size, KV, D)``; scanned layer groups
    carry a leading layer axis) plus per-slot ``pos`` (num_slots,) i32.
    Block tables ride as a separate operand of the decode step — they
    are host-managed by the engine's allocator.
    """
    ok, why = paged_supported(cfg)
    if not ok:
        raise NotImplementedError(f"paged KV cache: {why}")
    pat, n, prefix, tail = stack_pattern(cfg)

    def pages():
        return {"k": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                                cfg.head_dim), dtype)}

    cache = {}
    for i, _ in enumerate(prefix):
        cache[f"prefix{i}"] = pages()
    if n > 0:
        for s, _ in enumerate(pat):
            one = pages()
            cache[f"scan{s}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)
    for i, _ in enumerate(tail):
        cache[f"tail{i}"] = pages()
    cache["pos"] = jnp.zeros((num_slots,), jnp.int32)
    return cache


def write_paged(cache: dict, one: dict, slot, table_row,
                seq_len: int) -> dict:
    """Scatter a freshly-prefilled single-sequence cache (batch dim 1,
    what ``model.prefill`` returns for a (1, S) batch with window=None:
    positions 0..S-1 at cache rows 0..S-1) into the page pool at the
    blocks named by ``table_row`` (nb,) i32, and set ``pos[slot]`` to
    ``seq_len``.  ``slot``/``table_row`` may be traced; ``seq_len`` is
    static (the admission prefill bucket), so one jitted executable
    serves every slot/table.
    """
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big.at[slot].set(jnp.asarray(seq_len, big.dtype))
        else:
            if key.startswith("scan"):
                # leading layer axis: scatter each layer's pages with
                # the same (shared) table row
                out[key] = jax.tree.map(
                    lambda pages, o: jax.vmap(
                        lambda pg, sq: paged_lib.scatter_prefill(
                            pg, sq, table_row, seq_len)
                    )(pages, o[:, 0]),
                    big, one[key])
            else:
                out[key] = jax.tree.map(
                    lambda pages, o: paged_lib.scatter_prefill(
                        pages, o[0], table_row, seq_len),
                    big, one[key])
    return out


def prefill_chunk_paged(params: dict, x: Array, positions: Array,
                        table_row: Array, cfg, cache: dict,
                        use_pallas: bool = False):
    """Run ONE prompt chunk through the stack against the paged cache.

    x: (1, T, D) embedded chunk; positions: (T,) its absolute positions
    ``ctx_len .. ctx_len + T - 1`` (traced); table_row: (nb,) i32.
    Every attention layer scatters the chunk's K/V into its page pool
    at those positions and attends full-over-prefix / causal-in-chunk
    (``_attn_chunk_paged``).  Returns (x, new_cache, aux) — the caller
    (``model.prefill_chunk``) owns the final norm / logits / ``pos``
    bookkeeping.
    """
    ctx = {"positions": positions, "table_row": table_row,
           "use_pallas": use_pallas}
    return apply_stack(params, x, ctx, cfg, cache=cache, mode="chunk")


def prefill_chunks_paged_batched(params: dict, x: Array, ctx: dict, cfg,
                                 cache: dict):
    """Run one iteration's PACKED multi-chunk batch through the stack.

    x: (1, TT, D) embedded packed tokens (every scheduled chunk of the
    iteration back to back plus padding); ctx: the fused-chunk context
    (``positions``/``token_chunk``/``local``/``valid`` per packed row,
    ``meta`` rows ``[slot, ctx_len, chunk_len, q_offset]``,
    ``table_rows`` (C, nb), static ``chunk_pad`` and ``use_pallas``).
    Every attention layer scatters ALL chunks' K/V into its page pools
    and attends full-over-prefix / causal-in-chunk per chunk
    (``_attn_chunks_paged``) — one launch for the whole plan instead of
    one per chunk.  Returns (x, new_cache, aux); the caller
    (``model.prefill_chunks``) owns the final norm / per-chunk logits /
    ``pos`` bookkeeping.
    """
    return apply_stack(params, x, ctx, cfg, cache=cache, mode="chunks")


def copy_paged_block(cache: dict, src, dst) -> dict:
    """Copy-on-write page copy: duplicate physical block ``src`` into
    ``dst`` across every layer's K/V page pools (the prefix cache's
    full-match admission — see ``kvcache.prefix``).  ``src``/``dst``
    are traced scalars; scanned layer groups carry a leading layer
    axis, vmapped over so ``paged.copy_block`` is the single copy
    implementation.
    """
    copy = lambda pg: paged_lib.copy_block(pg, src, dst)  # noqa: E731
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big
        elif key.startswith("scan"):
            out[key] = jax.tree.map(jax.vmap(copy), big)
        else:
            out[key] = jax.tree.map(copy, big)
    return out


def write_slot(cache: dict, one: dict, slot) -> dict:
    """Scatter a freshly-prefilled single-sequence cache (batch dim 1,
    scalar pos, (W,) slot_pos — exactly what ``model.prefill`` returns
    for a (1, S) batch) into row ``slot`` of a per-slot decode cache.

    Every per-layer KV/state row of the recycled slot is REPLACED and
    its slot_pos row reset, so no state from the evicted sequence can
    leak into the admitted one.  ``slot`` may be a traced index — the
    whole update jit-compiles to dynamic-update-slices.
    """
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big.at[slot].set(one["pos"].astype(big.dtype))
        elif key == "slot_pos":
            out[key] = big.at[slot].set(one["slot_pos"])
        else:
            # scanned layer caches carry a leading layer axis; batch is
            # axis 1 there and axis 0 for prefix/tail layer caches.
            ax = 1 if key.startswith("scan") else 0
            out[key] = jax.tree.map(
                lambda b, o: lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis=ax),
                big, one[key])
    return out
