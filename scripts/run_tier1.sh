#!/usr/bin/env bash
# Tier-1 verify in one command: pins PYTHONPATH=src and runs the suite.
#
#   scripts/run_tier1.sh              # full suite
#   scripts/run_tier1.sh -m "not slow"  # fast lane (skips >1-min tests)
#
# Extra args are passed straight to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
