"""Architecture registry: ``--arch <id>`` lookup for every assigned config.

``get_config(arch_id)`` returns the full paper-exact ModelConfig;
``get_smoke_config(arch_id)`` returns the reduced same-family variant used
by the CPU smoke tests (2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import importlib

from .base import INPUT_SHAPES, InputShape, ModelConfig, shape_applicable

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "minitron-4b": "minitron_4b",
    "yi-6b": "yi_6b",
    "mixtral-8x22b": "mixtral_8x22b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "all_configs", "get_config", "get_smoke_config", "shape_applicable",
]
