"""Hypothesis property tests over system invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import rulegen
from repro.models import transformer
from repro.serving.engine import hash_tokenize

text_strategy = st.text(
    alphabet=st.characters(codec="ascii"), min_size=0, max_size=300)


@settings(max_examples=80, deadline=None)
@given(text=text_strategy)
def test_rulegen_total_on_arbitrary_text(text):
    """RULEGEN never crashes and always returns finite non-negative
    intensities — it sits on the request hot path."""
    r = rulegen.rulegen(text)
    assert r.shape == (6,)
    assert np.isfinite(r).all()
    assert (r >= 0).all()
    f = rulegen.features(text)
    assert f.shape == (rulegen.FEATURE_DIM,)
    assert np.isfinite(f).all()
    s = rulegen.single_rule_score(text)
    assert np.isfinite(s) and s >= 0


@settings(max_examples=40, deadline=None)
@given(text=text_strategy, vocab=st.integers(10, 50000),
       max_len=st.integers(1, 64))
def test_hash_tokenize_in_range(text, vocab, max_len):
    toks = hash_tokenize(text, vocab, max_len)
    assert 1 <= len(toks) <= max(max_len, 1)
    assert all(2 <= t < vocab for t in toks)


@settings(max_examples=40, deadline=None)
@given(cap=st.integers(1, 64), seq=st.integers(0, 200))
def test_prefill_slot_pos_invariants(cap, seq):
    """Ring-buffer slot map: every kept position is one of the last `cap`
    prefilled positions, each exactly once, at slot pos % cap."""
    sp = np.asarray(transformer.prefill_slot_pos(cap, seq))
    assert sp.shape == (cap,)
    kept = sp[sp < 2 ** 29]
    expect = np.arange(max(0, seq - cap), seq)
    assert sorted(kept.tolist()) == expect.tolist()
    for pos in kept:
        assert sp[pos % cap] == pos


@settings(max_examples=30, deadline=None)
@given(cap=st.integers(1, 32), seq=st.integers(1, 80),
       extra=st.integers(1, 40))
def test_ring_cache_decode_continuation(cap, seq, extra):
    """Writing tokens one-by-one after prefill keeps the slot map exactly
    consistent with a fresh prefill of the longer sequence."""
    sp = jnp.asarray(transformer.prefill_slot_pos(cap, seq))
    for pos in range(seq, seq + extra):
        sp = sp.at[pos % cap].set(pos)
    want = transformer.prefill_slot_pos(cap, seq + extra)
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(want))
