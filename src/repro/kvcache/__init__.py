"""Paged KV-cache subsystem (vLLM-style block tables).

PR 1's continuous engine reserves a contiguous ``(slots, max_len)`` KV
cache, so concurrency is pinned to the worst-case output length — the
exact uncertainty-inflated bound RT-LM identifies.  This package
decouples the two: KV memory is a pool of fixed-size blocks, sequences
own *block tables*, and memory scales with live tokens instead of slots.

  allocator.BlockAllocator — host-side free-list allocator with
      per-sequence block tables and used/free accounting.
  allocator.blocks_for_tokens — the shared memory formula
      ``ceil(tokens / block_size)`` used by the engine's admission gate
      and the simulator's block-budget model (they must agree exactly
      for engine-vs-sim parity).
  paged.PagedKVCache — device-side paged K/V store (one
      ``(num_blocks, block_size, kv_heads, head_dim)`` array pair per
      layer) plus the pure-jnp gather/scatter primitives the model's
      paged decode path and the Pallas paged kernel are built on.

Wiring: models/transformer.py (``init_paged_cache`` / ``write_paged`` /
paged decode attention), serving/engine.py (``kv="paged"`` for
``mode="continuous"``), core/simulator.py (block-budget admission),
kernels/paged_decode_attention.py (TPU flash-decode over a block table).
"""

from .allocator import BlockAllocator, blocks_for_tokens  # noqa: F401
from .paged import PagedKVCache  # noqa: F401
