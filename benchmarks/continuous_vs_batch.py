"""Head-to-head: continuous (iteration-level) batching vs the paper's
run-to-completion batch mode, on a heterogeneous-output-length workload.

Two measurements of the same trace:

  * ``sim``    — persona latency model, deterministic (the number the
    acceptance gate asserts on: throughput ratio and per-request mean
    response).
  * ``engine`` — the REAL JAX engine (tiny config on CPU), wall-clock
    per prefill/decode-step, demonstrating the same effect end-to-end.

The workload is bimodal output lengths (short tail / long tail, EOS
disabled so lengths are exact): run-to-completion pays the longest
member of every formed batch, continuous batching recycles each slot
the step its sequence finishes.

A third column (``run_paged``) compares the two CONTINUOUS KV layouts
at an EQUAL KV-memory budget: C contiguous slots of max_len tokens vs
the same token budget as a paged block pool (repro.kvcache) with the
slot count raised — paging admits strictly more concurrent sequences
because short requests reserve ceil((S + cap - 1)/block) blocks instead
of a whole max_len slot.  Results land in
experiments/bench/paged_vs_contiguous.json.

    PYTHONPATH=src python -m benchmarks.continuous_vs_batch
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator

from . import common

N_REQUESTS = 96
SHORT, LONG = 4, 48
LONG_FRAC = 0.25
BATCH_SLOTS = 8
SEED = 0

# paged-vs-contiguous column: equal KV budget, more slots for paged
INPUT_BUCKET = 8
KV_BLOCK = 16
PAGED_SLOTS = 3 * BATCH_SLOTS


def build_workload(n=N_REQUESTS, seed=SEED, *, short=SHORT, long_len=LONG,
                   long_frac=LONG_FRAC, window=0.5):
    """Bimodal-output workload shared by the serving benchmarks
    (prefill_interference.py re-parameterizes it)."""
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], n + 64, seed=seed)
    train, test = datagen.train_test_split(corpus, train_frac=0.4)
    rng = np.random.default_rng(seed)
    caps = np.where(rng.random(n) < long_frac, long_len, short).astype(int)
    # saturated regime: everything arrives inside the first batching
    # window, so the comparison isolates execution-model differences
    arrivals = np.sort(rng.uniform(0.0, window, size=n))
    return train, test[:n], caps.tolist(), arrivals.tolist()


def persona_for_bench(batch_size=BATCH_SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


def sim_tasks_for(test, caps, arrivals, profile, persona, xi=2.0):
    out = []
    for i, (t, c, r) in enumerate(zip(test, caps, arrivals)):
        u = profile.predictor.score(t.text)
        d = prio.priority_point(r, len(t.text.split()), persona.phi,
                                None, xi=xi)
        st = prio.SimTask(task=t, u=float(max(u, 0.0)), r=float(r), d=d,
                          input_len=float(len(t.text.split())),
                          true_out_len=int(c))
        out.append(st)
    return out


def run_sim(policy_name="fifo", seed=SEED):
    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload(seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    tasks = sim_tasks_for(test, caps, arrivals, profile, persona)
    pcfg = profile.policy_config()
    rtc = simulator.run_policy(tasks, policy_name, persona, pcfg,
                               mode="batch")
    cont = simulator.run_policy(tasks, policy_name, persona, pcfg,
                                mode="continuous")
    return {
        "batch": rtc.summary(),
        "continuous": cont.summary(),
        "throughput_ratio": cont.throughput_per_min / rtc.throughput_per_min,
        "mean_response_ratio": cont.mean_response / rtc.mean_response,
    }


def run_engine(policy_name="fifo", n=32, seed=SEED):
    """Same trace on the real JAX engine (tiny config, wall-clock)."""
    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload(n=n, seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for mode in ("batch", "continuous"):
        policy = sched.POLICIES[policy_name](persona,
                                             profile.policy_config())
        eng = ServingEngine(params, cfg, policy, profile, input_bucket=8,
                            max_new_tokens=LONG, mode=mode, eos_id=-1)
        reqs = [Request(text=t.text, arrival=a, task_id=i,
                        max_new_tokens=c)
                for i, (t, c, a) in enumerate(zip(test, caps, arrivals))]
        res = eng.serve(reqs)
        out[mode] = {k: res[k] for k in
                     ("mean_response_s", "max_response_s",
                      "throughput_per_min", "scheduler_overhead_s")}
    out["throughput_ratio"] = (out["continuous"]["throughput_per_min"]
                               / out["batch"]["throughput_per_min"])
    out["mean_response_ratio"] = (out["continuous"]["mean_response_s"]
                                  / out["batch"]["mean_response_s"])
    return out


def _kv_summary(res: dict) -> dict:
    return {k: res[k] for k in
            ("mean_response_s", "throughput_per_min", "peak_concurrency",
             "kv_util_peak", "kv_util_mean", "rejected_for_memory", "kv")}


def run_paged(policy_name="fifo", n_engine=32, seed=SEED):
    """Contiguous vs paged continuous engines at EQUAL KV-memory budget.

    Budget = what the contiguous engine reserves (BATCH_SLOTS * max_len
    tokens); the paged engine gets that budget as blocks plus a larger
    slot count, so the block pool — not worst-case length — bounds
    concurrency.  Outputs the acceptance numbers: peak concurrency
    (paged strictly higher), throughput, KV utilization, rejections.
    """
    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    from repro.kvcache.paged import default_num_blocks

    persona = persona_for_bench()
    max_len = INPUT_BUCKET + LONG + 8
    budget_blocks = default_num_blocks(BATCH_SLOTS, max_len, KV_BLOCK)

    # --- deterministic sim column (full trace) ---
    train, test, caps, arrivals = build_workload(seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    tasks = sim_tasks_for(test, caps, arrivals, profile, persona)
    pcfg = profile.policy_config()
    cont = simulator.run_policy(tasks, policy_name, persona, pcfg,
                                mode="continuous")
    paged = simulator.run_policy(tasks, policy_name, persona, pcfg,
                                 mode="continuous",
                                 num_slots=PAGED_SLOTS,
                                 kv_block_size=KV_BLOCK,
                                 kv_num_blocks=budget_blocks,
                                 prompt_len=INPUT_BUCKET)
    sim = {
        "contiguous": dict(cont.summary(),
                           peak_concurrency=cont.peak_concurrency,
                           kv_util_peak=cont.kv_util_peak,
                           kv_util_mean=cont.kv_util_mean),
        "paged": dict(paged.summary(),
                      peak_concurrency=paged.peak_concurrency,
                      kv_util_peak=paged.kv_util_peak,
                      kv_util_mean=paged.kv_util_mean,
                      kv_rejected=paged.kv_rejected),
        "concurrency_gain": paged.peak_concurrency / cont.peak_concurrency,
        "throughput_ratio": (paged.throughput_per_min
                             / cont.throughput_per_min),
    }

    # --- real JAX engine column (tiny config, wall-clock) ---
    train, test, caps, arrivals = build_workload(n=n_engine, seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    eng = {}
    for kv, kw in (("contiguous", {}),
                   ("paged", dict(num_slots=PAGED_SLOTS,
                                  kv_block_size=KV_BLOCK,
                                  kv_num_blocks=budget_blocks))):
        policy = sched.POLICIES[policy_name](persona,
                                             profile.policy_config())
        e = ServingEngine(params, cfg, policy, profile,
                          input_bucket=INPUT_BUCKET, max_new_tokens=LONG,
                          mode="continuous", eos_id=-1, kv=kv, **kw)
        reqs = [Request(text=t.text, arrival=a, task_id=i,
                        max_new_tokens=c)
                for i, (t, c, a) in enumerate(zip(test, caps, arrivals))]
        eng[kv] = _kv_summary(e.serve(reqs))
        if kv == "paged":
            e.allocator.check_no_leaks()
    eng["concurrency_gain"] = (eng["paged"]["peak_concurrency"]
                               / eng["contiguous"]["peak_concurrency"])
    eng["throughput_ratio"] = (eng["paged"]["throughput_per_min"]
                               / eng["contiguous"]["throughput_per_min"])
    return {
        "kv_block_size": KV_BLOCK,
        "budget_blocks": budget_blocks,
        "budget_tokens": budget_blocks * KV_BLOCK,
        "contiguous_slots": BATCH_SLOTS,
        "paged_slots": PAGED_SLOTS,
        "sim": sim,
        "engine": eng,
    }


def run_decode_dispatch(policy_name="fifo", n_engine=24, seed=SEED,
                        steps=4):
    """Async host pipeline: decode dispatches per serve at N=1 vs N=4.

    Every decode window is ONE device launch covering N steps, so
    dispatches per executed decode step fall EXACTLY Nx (1 -> 1/N,
    asserted) while the greedy tokens stay identical (asserted) — this
    benchmark is the acceptance gate for the multi-step pipeline, not
    just a reporter.  The END-TO-END launch-count reduction is
    workload-dependent and lands below N: admission waits for window
    boundaries and finished slots ride their window to its end
    (eviction in arrears), so windows carry dead slot-steps —
    ``step_inflation_x`` reports that overhang cost next to the
    dispatch win, and a >= 2x floor is asserted as the regression
    gate.  Results land in experiments/bench/decode_dispatch.json.
    """
    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    persona = persona_for_bench()
    # decode-dominated, all-at-once variant of the bimodal workload:
    # caps of 4 would spend most of every 4-step window on finished
    # slots (the ratio would measure tail waste, not the pipeline),
    # and staggered arrivals race real wall-clock time — admission
    # timing, hence the launch counts, would jitter run to run
    train, test, caps, arrivals = build_workload(n=n_engine, seed=seed,
                                                 short=16, window=0.0)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [Request(text=t.text, arrival=a, task_id=i, max_new_tokens=c)
            for i, (t, c, a) in enumerate(zip(test, caps, arrivals))]
    out = {"decode_steps": steps, "n_requests": n_engine}
    for prefill, pkw in (("stall", {}),
                         ("chunked", dict(chunk_size=4, token_budget=16))):
        col, tokens = {}, {}
        for n in (1, steps):
            policy = sched.POLICIES[policy_name](persona,
                                                 profile.policy_config())
            eng = ServingEngine(params, cfg, policy, profile,
                                input_bucket=INPUT_BUCKET,
                                max_new_tokens=LONG, mode="continuous",
                                eos_id=-1, kv="paged", prefill=prefill,
                                decode_steps=n, **pkw)
            t0 = time.time()
            res = eng.serve(reqs)
            eng.allocator.check_no_leaks()
            # every window launch executes exactly N steps
            assert (res["decode_steps_executed"]
                    == n * res["decode_dispatches"]), (
                f"{prefill} N={n}: steps_executed != N * dispatches")
            tokens[n] = {t.task.task_id: list(t.task.out_tokens)
                         for t in res["tasks"]}
            col[f"n{n}"] = {
                "decode_dispatches": res["decode_dispatches"],
                "decode_steps_executed": res["decode_steps_executed"],
                "steps_per_launch": (res["decode_steps_executed"]
                                     / max(1, res["decode_dispatches"])),
                "mean_response_s": res["mean_response_s"],
                "wall_s": time.time() - t0,
            }
        # multi-step windows must not change greedy output ...
        assert tokens[1] == tokens[steps], (
            f"{prefill}: tokens differ between N=1 and N={steps}")
        # ... and the per-step dispatch rate must fall EXACTLY Nx
        # (1 launch/step -> 1 launch per N steps; exact because every
        # window executes its full N steps, finished slots included)
        per_step = ((col["n1"]["decode_dispatches"]
                     / col["n1"]["decode_steps_executed"])
                    / (col[f"n{steps}"]["decode_dispatches"]
                       / col[f"n{steps}"]["decode_steps_executed"]))
        assert abs(per_step - steps) < 1e-9, (
            f"{prefill}: per-step dispatch reduction {per_step} != {steps}")
        # end-to-end launch count: workload-dependent (window
        # quantization adds dead slot-steps), floor-asserted
        ratio = (col["n1"]["decode_dispatches"]
                 / max(1, col[f"n{steps}"]["decode_dispatches"]))
        assert ratio >= 2.5, (
            f"{prefill}: dispatch reduction {ratio:.2f}x < 2.5x floor")
        col["dispatch_per_step_reduction_x"] = per_step
        col["dispatch_reduction_x"] = ratio
        col["step_inflation_x"] = (
            col[f"n{steps}"]["decode_steps_executed"]
            / col["n1"]["decode_steps_executed"])
        out[prefill] = col
    return out


def main(seed=SEED):
    t0 = time.time()
    sim = run_sim("fifo", seed=seed)
    common.save("continuous_vs_batch_sim", sim)
    common.emit("continuous_vs_batch_sim", time.time() - t0,
                f"throughput_x={sim['throughput_ratio']:.2f},"
                f"mean_response_x={sim['mean_response_ratio']:.2f}")
    t0 = time.time()
    eng = run_engine("fifo", seed=seed)
    common.save("continuous_vs_batch_engine", eng)
    common.emit("continuous_vs_batch_engine", time.time() - t0,
                f"throughput_x={eng['throughput_ratio']:.2f},"
                f"mean_response_x={eng['mean_response_ratio']:.2f}")
    t0 = time.time()
    paged = run_paged("fifo", seed=seed)
    common.save("paged_vs_contiguous", paged)
    common.emit("paged_vs_contiguous", time.time() - t0,
                f"sim_concurrency_x={paged['sim']['concurrency_gain']:.2f},"
                f"engine_concurrency_x="
                f"{paged['engine']['concurrency_gain']:.2f},"
                f"engine_throughput_x="
                f"{paged['engine']['throughput_ratio']:.2f}")
    t0 = time.time()
    dd = run_decode_dispatch("fifo", seed=seed)
    common.save("decode_dispatch", dd)
    spl = dd["stall"]["n%d" % dd["decode_steps"]]["steps_per_launch"]
    common.emit("decode_dispatch", time.time() - t0,
                f"stall_dispatch_x={dd['stall']['dispatch_reduction_x']:.2f},"
                f"chunked_dispatch_x="
                f"{dd['chunked']['dispatch_reduction_x']:.2f},"
                f"steps_per_launch={spl:.0f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
