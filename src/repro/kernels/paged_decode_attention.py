"""Paged flash-decode: single-token GQA attention over a block table.

Same memory-bound regime and online-softmax structure as
``decode_attention.py``, but the KV cache is a pool of fixed-size pages
(``(num_pages, block_size, KV, D)``) and each sequence names its pages
through a ``(B, num_blocks)`` block table — KV memory scales with live
tokens, not ``B * max_len`` (vLLM's PagedAttention, here as a Pallas
TPU kernel).

The indirection happens in the BlockSpec index_map, not the kernel
body: the block table rides in as a scalar-prefetch operand
(``PrefetchScalarGridSpec``), so when the sequential innermost grid
dimension walks a sequence's logical blocks, Mosaic's pipeline DMAs the
*physical* page ``tables[b, i]`` into VMEM — an indirect gather at full
copy bandwidth, with no (B, max_len) contiguous view ever materialized
(the pure-jnp fallback in ``kernels/ref.py`` materializes exactly that
view; it is the semantic oracle, not the production path).

  grid = (B, KV, nb) — innermost sequential over table entries;
  per step: q-group tile (G, D) x page (block_size, D) on the MXU,
  masked by ``logical_pos < seq_len`` (table padding resolves to page 0,
  fully masked); running (m, l, acc) scratch identical to decode_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_fd_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                     m_scr, l_scr, acc_scr, *, scale: float,
                     block_size: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, D) — page tables[b,ki]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (G, bs)
    # logical positions covered by this table entry; padding entries
    # (ki >= ceil(seq_len / bs)) mask out entirely
    pos = (ki * block_size
           + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    valid = pos < lens_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # re-mask after the shift: when every position so far is masked,
    # m_new == s == NEG_INF and exp(s - m_new) would be 1, averaging
    # garbage page contents into the row (a seq_len == 0 row then
    # returns zeros instead)
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_flash_decode_attention(q, k_pages, v_pages, block_tables,
                                 seq_lens, *, interpret: bool = False):
    """q: (B, H, D); pages: (N, bs, KV, D); block_tables: (B, nb) i32
    physical page ids (pad with any valid id, e.g. 0); seq_lens: (B,)
    i32 valid logical lengths.  Returns (B, H, D).

    A ``seq_len == 0`` row attends to nothing and returns zeros (the
    pure-jnp oracle softmaxes over all -inf and yields NaN there, so
    only rows with ``seq_len >= 1`` are comparable against it).
    """
    B, H, D = q.shape
    N, bs, KV, _ = k_pages.shape
    _, nb = block_tables.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)

    qt = q.reshape(B, KV, G, D)
    kt = k_pages.transpose(2, 0, 1, 3)           # (KV, N, bs, D)
    vt = v_pages.transpose(2, 0, 1, 3)
    tables = block_tables.astype(jnp.int32)
    lens = seq_lens.astype(jnp.int32)

    kernel = functools.partial(_paged_fd_kernel, scale=scale,
                               block_size=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_tables, seq_lens
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, i, t, s: (b, h, 0, 0)),
            # the indirection: page tables[b, i] streams into VMEM
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, t, s: (h, t[b, i], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, t, s: (h, t[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, i, t, s: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(tables, lens, qt, kt, vt)
    return out.reshape(B, H, D)
