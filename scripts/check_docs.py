#!/usr/bin/env python
"""Docs check: every intra-repo markdown link must resolve.

Scans the repo's tracked-ish markdown files (root, docs/, and any
*.md under src/ or tests/) for inline links/images
``[text](target)`` and validates that relative targets exist on disk
(anchors are stripped; external schemes and bare anchors are
skipped).  Exits non-zero listing every broken link — run by
scripts/ci.sh and the CI workflow's docs step.

    python scripts/check_docs.py [root]
"""

from __future__ import annotations

import os
import re
import sys

# inline markdown link/image: [text](target) — excludes ``](`` inside
# code spans well enough for this repo's docs; reference-style links
# are not used here.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://", "#")


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".pytest_cache",
                                    "node_modules", ".claude")]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check(root: str):
    broken = []
    n_links = 0
    for path in sorted(md_files(root)):
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        # drop fenced code blocks: their [x](y) are examples, not links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            n_links += 1
            if not os.path.exists(resolved):
                broken.append((path, target))
    return n_links, broken


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "..")
    root = os.path.abspath(root)
    n_links, broken = check(root)
    if broken:
        print(f"BROKEN markdown links ({len(broken)}):")
        for path, target in broken:
            print(f"  {os.path.relpath(path, root)} -> {target}")
        return 1
    print(f"docs OK: {n_links} intra-repo markdown links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
