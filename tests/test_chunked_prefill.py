"""Chunked-prefill subsystem coverage (ISSUE 3).

Acceptance properties:

  * scheduler — the token budget is never exceeded, chunks cover each
    prompt in order, FIFO tie-break is starvation-free (deterministic
    forms here; hypothesis forms in tests/test_properties.py);
  * model — sequential ``prefill_chunk`` calls reproduce the stall
    ``prefill_into_paged`` cache and last-position logits BIT FOR BIT
    across chunk sizes (bf16 cache round-trips are lossless and the
    chunk attention runs the same recipe as the stall prefill);
  * engine — ``prefill="chunked"`` output is token-for-token identical
    to the stall-admission paged engine on the same workload;
  * engine-vs-sim — ``simulate_continuous(prefill="chunked")`` drives
    the same ChunkScheduler and reproduces the engine's completion
    order and per-iteration budget trace, including under a tight
    block budget with memory rejections;
  * kernels — the Pallas ``paged_decode_attention`` routing flag
    (``use_pallas=``) matches the jnp gather path token for token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.kvcache import BlockAllocator
from repro.kvcache.paged import PagedKVCache
from repro.models import model as model_lib
from repro.prefill import ChunkScheduler
from repro.serving import generate
from repro.serving.engine import Request, ServingEngine, hash_tokenize

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]
CHUNK = 3
BUDGET = 8


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    return cfg, params, persona, profile, test


def _requests(test, caps):
    return [Request(text=t.text, arrival=0.0, task_id=i,
                    max_new_tokens=c)
            for i, (t, c) in enumerate(zip(test, caps))]


def _sim_tasks(test, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(test, caps)):
        u = profile.predictor.score(t.text)
        d = prio.priority_point(0.0, len(t.text.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t.text, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.text.split())), true_out_len=int(c)))
    return out


def _engine(setup, policy_name="fifo", **kw):
    cfg, params, persona, profile, _ = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    return ServingEngine(
        params, cfg, sched.POLICIES[policy_name](persona, pcfg), profile,
        input_bucket=BUCKET, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1, **kw)


# ---------------------------------------------------------------------------
# ChunkScheduler (deterministic; hypothesis forms in test_properties.py)
# ---------------------------------------------------------------------------


def test_scheduler_budget_and_order():
    s = ChunkScheduler(chunk_size=4, token_budget=10)
    s.add("a", slot=0, total=10, priority=0.0)
    s.add("b", slot=1, total=6, priority=0.0)
    covered = {"a": [], "b": []}
    rounds = 0
    while s.has_jobs:
        decode = min(rounds, 3)          # growing decode load
        plans = s.schedule(decode)
        assert sum(p.length for p in plans) <= max(0, 10 - decode)
        for p in plans:
            covered[p.job.task].append((p.start, p.length))
        rounds += 1
        assert rounds < 50
    for total, key in ((10, "a"), (6, "b")):
        pos = 0
        for start, length in covered[key]:
            assert start == pos           # in order, no gaps
            pos += length
        assert pos == total               # full coverage
    # FIFO tie-break: equal priorities -> "a" (admitted first) finishes
    # its prefill no later than "b"
    assert covered["a"][0][0] == 0


def test_scheduler_priority_order_and_tail_chunks():
    s = ChunkScheduler(chunk_size=4, token_budget=6)
    s.add("low", slot=0, total=6, priority=-1.0)
    s.add("high", slot=1, total=6, priority=5.0)
    plans = s.schedule(0)
    # high priority first; its tail chunk (2) rides along; low's first
    # chunk (4) no longer fits in the remaining 0 tokens
    assert [(p.job.task, p.start, p.length) for p in plans] == [
        ("high", 0, 4), ("high", 4, 2)]
    assert plans[-1].finishes
    plans = s.schedule(0)
    assert [(p.job.task, p.start, p.length) for p in plans] == [
        ("low", 0, 4), ("low", 4, 2)]


def test_scheduler_work_conservation():
    """Whenever jobs pend and the remainder fits a whole chunk, at
    least one chunk is scheduled (bounded wait under FIFO)."""
    s = ChunkScheduler(chunk_size=4, token_budget=8)
    for j in range(5):
        s.add(j, slot=j, total=12, priority=0.0)
    while s.has_jobs:
        plans = s.schedule(4)            # remainder = 4 = one chunk
        assert plans, "scheduler idled with pending work and headroom"


def test_scheduler_validation():
    with pytest.raises(ValueError, match="chunk_size"):
        ChunkScheduler(0, 8)
    with pytest.raises(ValueError, match="live-lock"):
        ChunkScheduler(8, 4)
    s = ChunkScheduler(4, 8)
    with pytest.raises(ValueError, match="total"):
        s.add("x", 0, 0, 0.0)


# ---------------------------------------------------------------------------
# model-level parity: chunked prefill == stall prefill, bit for bit
# ---------------------------------------------------------------------------


def _packed_iteration(ragged_fn, params, cache, chunks, kvc, *,
                      num_slots, key):
    """Drive one fused launch the way the engine does (same
    ``build_packed_arrays`` layout): chunks is a list of
    (slot, toks, start, length); key = (TT_pad, C_pad, T_pad)."""
    from repro.prefill import build_packed_arrays
    entries = [(slot, start, toks[start:start + ln], kvc.tables[slot])
               for slot, toks, start, ln in chunks]
    tokens, token_chunk, meta, tabs = build_packed_arrays(
        key, entries, pad_slot=num_slots,
        table_width=kvc.max_blocks_per_seq, trash_block=kvc.trash_block)
    return ragged_fn(params, cache, {"tokens": jnp.asarray(tokens)},
                     jnp.asarray(token_chunk), jnp.asarray(meta),
                     jnp.asarray(tabs), chunk_pad=key[2])


def test_prefill_chunks_matches_sequential(setup):
    """The FUSED packed executable reproduces sequential per-chunk
    ``prefill_chunk`` calls BIT FOR BIT — caches and last-position
    logits — across two interleaved iterations of two requests with
    ragged chunk lengths (including padding chunks and columns)."""
    cfg, params, _, _, test = setup
    S, bs = BUCKET, 4
    max_len = S + MAX_NEW + 8
    kvc_a = PagedKVCache(cfg, 2, 16, bs, max_len)
    kvc_b = PagedKVCache(cfg, 2, 16, bs, max_len)
    alloc = BlockAllocator(16, bs)
    toks = {}
    for s in range(2):
        blocks = alloc.allocate_n(s, alloc.blocks_for(S))
        kvc_a.set_table(s, blocks)
        kvc_b.set_table(s, blocks)
        arr = np.zeros((S,), np.int32)
        seq = hash_tokenize(test[s].text, cfg.vocab_size, S)
        arr[S - len(seq):] = seq
        toks[s] = arr
    # iteration 1: slot0 [0:3], slot1 [0:5]; iteration 2: the tails
    iters = [[(0, toks[0], 0, 3), (1, toks[1], 0, 5)],
             [(0, toks[0], 3, 5), (1, toks[1], 5, 3)]]
    cf = generate.make_chunk_prefill_fn(cfg, use_pallas=False)
    cache_a = kvc_a.state
    for it in iters:
        for slot, tk, start, ln in it:
            cache_a, logits_a = cf(
                params, cache_a,
                {"tokens": jnp.asarray(tk[None, start:start + ln])},
                jnp.int32(slot), kvc_a.table_row(slot), jnp.int32(start))
    rf = generate.make_ragged_prefill_fn(cfg, use_pallas=False)
    cache_b = kvc_b.state
    for it in iters:
        # padded buckets deliberately LARGER than the real work (a
        # padding chunk row plus padding columns must be inert)
        cache_b, logits_b = _packed_iteration(
            rf, params, cache_b, it, kvc_b, num_slots=2, key=(16, 4, 8))
    for la, lb in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # row 1 of the fused logits is slot1's tail chunk — the same final
    # prompt position the last sequential call returned
    np.testing.assert_array_equal(np.asarray(logits_a),
                                  np.asarray(logits_b[1]))


def test_prefill_chunks_use_pallas_parity(setup):
    """The fused Pallas kernel path (interpret mode on CPU) matches the
    jnp fallback: identical page pools, argmax-identical logits."""
    cfg, params, _, _, test = setup
    S, bs = BUCKET, 4
    max_len = S + MAX_NEW + 8
    caches = {}
    for flag in (False, True):
        kvc = PagedKVCache(cfg, 2, 16, bs, max_len)
        alloc = BlockAllocator(16, bs)
        chunks = []
        for s in range(2):
            kvc.set_table(s, alloc.allocate_n(s, alloc.blocks_for(S)))
            arr = np.zeros((S,), np.int32)
            seq = hash_tokenize(test[s].text, cfg.vocab_size, S)
            arr[S - len(seq):] = seq
            chunks.append((s, arr, 0, S))
        rf = generate.make_ragged_prefill_fn(cfg, use_pallas=flag)
        cache, logits = _packed_iteration(
            rf, params, kvc.state, chunks, kvc, num_slots=2,
            key=(16, 2, 8))
        caches[flag] = (cache, logits)
    np.testing.assert_allclose(np.asarray(caches[True][1]),
                               np.asarray(caches[False][1]),
                               atol=5e-2, rtol=5e-2)
    assert (np.argmax(np.asarray(caches[True][1]), -1)
            == np.argmax(np.asarray(caches[False][1]), -1)).all()
    for la, lb in zip(jax.tree.leaves(caches[True][0]),
                      jax.tree.leaves(caches[False][0])):
        np.testing.assert_allclose(np.asarray(la).astype(np.float32),
                                   np.asarray(lb).astype(np.float32),
                                   atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("chunk", [3, 4, BUCKET])
def test_prefill_chunk_matches_full_prefill(setup, chunk):
    cfg, params, _, _, test = setup
    S, bs = BUCKET, 4
    max_len = S + MAX_NEW + 8
    kvc_a = PagedKVCache(cfg, 2, 16, bs, max_len)
    kvc_b = PagedKVCache(cfg, 2, 16, bs, max_len)
    alloc = BlockAllocator(16, bs)
    blocks = alloc.allocate_n(0, alloc.blocks_for(S))
    kvc_a.set_table(0, blocks)
    kvc_b.set_table(0, blocks)
    toks = np.zeros((1, S), np.int32)
    seq = hash_tokenize(test[0].text, cfg.vocab_size, S)
    toks[0, S - len(seq):] = seq

    pf = generate.make_paged_prefill_fn(cfg, max_len)
    cache_a, logits_a = pf(params, kvc_a.state,
                           {"tokens": jnp.asarray(toks)}, jnp.int32(0),
                           kvc_a.table_row(0))
    cf = generate.make_chunk_prefill_fn(cfg, use_pallas=False)
    cache_b = kvc_b.state
    done = 0
    while done < S:
        T = min(chunk, S - done)
        cache_b, logits_b = cf(
            params, cache_b, {"tokens": jnp.asarray(toks[:, done:done + T])},
            jnp.int32(0), kvc_b.table_row(0), jnp.int32(done))
        done += T
    np.testing.assert_array_equal(np.asarray(logits_a),
                                  np.asarray(logits_b))
    for la, lb in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# engine: token parity, metrics, engine-vs-sim parity
# ---------------------------------------------------------------------------


def test_chunked_matches_stall_token_for_token(setup):
    """Same workload: the chunked engine reorders WHEN prefill work
    runs, but every request's greedy tokens are identical to the
    stall-admission paged engine."""
    _, _, _, _, test = setup
    res = {}
    for pf, kw in (("stall", {}),
                   ("chunked", dict(chunk_size=CHUNK,
                                    token_budget=BUDGET))):
        eng = _engine(setup, kv="paged", kv_block_size=4, prefill=pf, **kw)
        res[pf] = eng.serve(_requests(test, CAPS))
        eng.allocator.check_no_leaks()
    stall = {t.task.task_id: t.task for t in res["stall"]["tasks"]}
    chnk = {t.task.task_id: t.task for t in res["chunked"]["tasks"]}
    for i, c in enumerate(CAPS):
        assert chnk[i].out_len == stall[i].out_len == c
        assert chnk[i].out_tokens == stall[i].out_tokens
    # the budget invariant held on the real engine's trace
    assert res["chunked"]["budget_trace"]
    for decode_toks, prefill_toks in res["chunked"]["budget_trace"]:
        assert prefill_toks <= max(0, BUDGET - decode_toks)
    assert res["chunked"]["prefill"]["kind"] == "chunked"
    # fused dispatch: the chunked engine issues EXACTLY ONE prefill
    # launch per iteration with scheduled chunks — never one per chunk
    trace = res["chunked"]["prefill_dispatch_trace"]
    assert len(trace) == len(res["chunked"]["budget_trace"])
    assert all(d in (0, 1) for d in trace)
    assert [d > 0 for d in trace] == \
        [p > 0 for _, p in res["chunked"]["budget_trace"]]
    assert res["chunked"]["prefill_dispatches"] == sum(trace)
    # and strictly fewer launches than the per-admission stall path
    # whenever prompts split into more than one chunk per iteration
    assert res["chunked"]["exec_cache_misses"] >= 1
    assert (res["chunked"]["exec_cache_hits"]
            + res["chunked"]["exec_cache_misses"]
            == res["chunked"]["prefill_dispatches"])


def test_tail_latency_metrics_reported(setup):
    """ttft/itl percentiles are reported for all engine modes and are
    internally consistent (first token never after completion)."""
    cfg, params, persona, profile, test = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    variants = {
        "batch": dict(mode="batch"),
        "continuous": dict(mode="continuous"),
        "paged": dict(mode="continuous", kv="paged", kv_block_size=4),
        "chunked": dict(mode="continuous", kv="paged", kv_block_size=4,
                        prefill="chunked", chunk_size=CHUNK,
                        token_budget=BUDGET),
    }
    for name, kw in variants.items():
        eng = ServingEngine(
            params, cfg, sched.POLICIES["fifo"](persona, pcfg), profile,
            input_bucket=BUCKET, max_new_tokens=MAX_NEW, eos_id=-1, **kw)
        res = eng.serve(_requests(test, CAPS[:6]))
        for key in ("ttft_p50", "ttft_p99", "itl_p50", "itl_p99"):
            assert key in res, (name, key)
            assert np.isfinite(res[key]) and res[key] >= 0.0
        assert res["ttft_p50"] <= res["ttft_p99"] + 1e-12
        assert res["itl_p50"] <= res["itl_p99"] + 1e-12
        for t in res["tasks"]:
            times = t.task.token_times
            assert len(times) == t.task.out_len
            assert times[0] <= t.task.finish + 1e-9
            assert all(b >= a - 1e-9 for a, b in zip(times, times[1:]))


@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_engine_vs_sim_chunked_parity(setup, policy_name):
    """The simulator's chunked-prefill mode reproduces the engine's
    completion order AND per-iteration budget trace exactly."""
    cfg, params, persona, profile, test = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _engine(setup, policy_name, kv="paged", kv_block_size=4,
                  prefill="chunked", chunk_size=CHUNK, token_budget=BUDGET)
    res = eng.serve(_requests(test, CAPS))
    sim = simulator.simulate_continuous(
        _sim_tasks(test, CAPS, profile, persona),
        sched.POLICIES[policy_name](persona, pcfg),
        prompt_len=BUCKET, prefill="chunked", chunk_size=CHUNK,
        token_budget=BUDGET)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["budget_trace"] == sim.budget_trace
    # dispatch + fused-executable-cache accounting parity
    assert res["prefill_dispatches"] == sim.prefill_dispatches
    assert res["prefill_dispatch_trace"] == sim.prefill_dispatch_trace
    assert res["exec_cache_hits"] == sim.exec_cache_hits
    assert res["exec_cache_misses"] == sim.exec_cache_misses


def test_engine_vs_sim_chunked_parity_tight_budget(setup):
    """Memory rejections and chunked prefill compose: the reservation
    gate decides identically in engine and simulator."""
    cfg, params, persona, profile, test = setup
    bs, nb, slots = 4, 7, 4
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _engine(setup, kv="paged", num_slots=slots, kv_block_size=bs,
                  kv_num_blocks=nb, prefill="chunked", chunk_size=CHUNK,
                  token_budget=BUDGET)
    res = eng.serve(_requests(test, CAPS))
    eng.allocator.check_no_leaks()
    assert res["rejected_for_memory"] > 0            # budget actually binds
    sim = simulator.simulate_continuous(
        _sim_tasks(test, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg),
        num_slots=slots, kv_block_size=bs, kv_num_blocks=nb,
        prompt_len=BUCKET, prefill="chunked", chunk_size=CHUNK,
        token_budget=BUDGET)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["rejected_for_memory"] == sim.kv_rejected
    assert res["budget_trace"] == sim.budget_trace
    assert res["prefill_dispatches"] == sim.prefill_dispatches
    assert res["prefill_dispatch_trace"] == sim.prefill_dispatch_trace
    assert res["exec_cache_hits"] == sim.exec_cache_hits
    assert res["exec_cache_misses"] == sim.exec_cache_misses


def test_engine_vs_sim_dispatch_parity_stall(setup):
    """Stall admission issues one prefill launch PER ADMISSION (the
    burst the fused path collapses); the simulator mirrors the total
    and the per-iteration burst sizes exactly."""
    cfg, params, persona, profile, test = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _engine(setup, kv="paged", kv_block_size=4)
    res = eng.serve(_requests(test, CAPS))
    sim = simulator.simulate_continuous(
        _sim_tasks(test, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg))
    assert res["prefill_dispatches"] == len(CAPS) == sim.prefill_dispatches
    assert res["prefill_dispatch_trace"] == sim.prefill_dispatch_trace
    # a burst of several admissions in one iteration means several
    # launches per iteration — the O(#admissions) regime
    assert max(res["prefill_dispatch_trace"]) > 1
    assert res["exec_cache_hits"] == sim.exec_cache_hits == 0
    assert res["exec_cache_misses"] == sim.exec_cache_misses == 0


def test_sim_chunked_bounds_itl_vs_stall():
    """Deterministic persona model: under a saturated admission burst,
    chunked prefill's p99 ITL (bounded by the token budget) comes in
    under stall admission's (bounded only by the burst size)."""
    persona = _persona(batch_size=8)
    n, prompt = 64, 32
    # bimodal lengths so evictions stagger: freed slots admit (and, in
    # stall mode, prefill) while the long requests are still decoding
    tasks = [prio.SimTask(task=i, u=5.0, r=0.0, d=4.0, input_len=5.0,
                          true_out_len=(24 if i % 4 == 0 else 6))
             for i in range(n)]
    import copy
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    stall = simulator.simulate_continuous(
        [copy.copy(t) for t in tasks],
        sched.POLICIES["fifo"](persona, pcfg), prompt_len=prompt)
    chunked = simulator.simulate_continuous(
        [copy.copy(t) for t in tasks],
        sched.POLICIES["fifo"](persona, pcfg), prompt_len=prompt,
        prefill="chunked", chunk_size=16, token_budget=24)
    assert chunked.itl_p99 < stall.itl_p99
    # the improvement holds through the body of the distribution too
    # (p90), and the new percentile fields are populated on both runs
    assert chunked.itl_p90 <= stall.itl_p90
    for res in (stall, chunked):
        assert res.itl_p50 <= res.itl_p90 <= res.itl_p99
        assert res.ttft_p50 <= res.ttft_p90 <= res.ttft_p99
        assert res.queue_wait_p50 <= res.queue_wait_p99
    assert len(chunked.tasks) == len(stall.tasks) == n


# ---------------------------------------------------------------------------
# Pallas routing flag (paged decode) — satellite of ISSUE 3
# ---------------------------------------------------------------------------


def test_paged_decode_use_pallas_flag_parity(setup):
    """decode_step_paged(use_pallas=True) (kernel in interpret mode on
    CPU) produces the same greedy tokens as the jnp gather path."""
    cfg, params, _, _, test = setup
    S, bs, C = BUCKET, 4, 2
    max_len = S + MAX_NEW + 8
    kvc = PagedKVCache(cfg, C, 16, bs, max_len)
    alloc = BlockAllocator(16, bs)
    pf = generate.make_paged_prefill_fn(cfg, max_len)
    cache = kvc.state
    for s in range(C):
        kvc.set_table(s, alloc.allocate_n(s, alloc.blocks_for(S)))
        toks = np.zeros((1, S), np.int32)
        seq = hash_tokenize(test[s].text, cfg.vocab_size, S)
        toks[0, S - len(seq):] = seq
        cache, _ = pf(params, cache, {"tokens": jnp.asarray(toks)},
                      jnp.int32(s), kvc.table_row(s))
    dec_ref = generate.make_paged_decode_fn(cfg, use_pallas=False)
    dec_pal = generate.make_paged_decode_fn(cfg, use_pallas=True)
    tok = jnp.asarray([[5], [7]], jnp.int32)
    ca = cb = cache
    for _ in range(3):
        ta, la, ca = dec_ref(params, ca, tok, kvc.tables_device())
        tb, lb, cb = dec_pal(params, cb, tok, kvc.tables_device())
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=5e-2, rtol=5e-2)
        tok = ta


def test_chunked_engine_validation(setup):
    cfg, _, persona, _, _ = setup
    pcfg = sched.PolicyConfig()
    policy = sched.POLICIES["fifo"](persona, pcfg)
    with pytest.raises(ValueError, match="chunked"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="contiguous", prefill="chunked")
    with pytest.raises(ValueError, match="prefill"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="paged", prefill="sarathi")
    with pytest.raises(ValueError, match="live-lock"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="paged", prefill="chunked", chunk_size=16,
                      token_budget=4)
    with pytest.raises(ValueError, match="chunked"):
        simulator.simulate_continuous(
            [], policy, prompt_len=0, prefill="chunked",
            chunk_size=4, token_budget=8)
