import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k [--multi-pod] [--out results.json] [--fsdp/--no-fsdp]

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the step
function against ShapeDtypeStruct inputs (no allocation), compiles, and
prints memory_analysis() (fits?) + cost_analysis() (FLOPs/bytes for
EXPERIMENTS.md §Roofline) + the parsed collective schedule.
"""

import argparse
import json
import sys
import time

import jax

from repro import configs
from repro.launch import analysis, mesh as mesh_lib, specs
from repro.sharding import context as shctx, policy as policy_lib


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            fsdp: bool = True, seq_parallel: bool = False,
            serving: bool = False, verbose: bool = True) -> dict:
    cfg = configs.get_config(arch)
    shape = configs.INPUT_SHAPES[shape_name]
    ok, reason = configs.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    policy = policy_lib.make_policy(mesh, fsdp=fsdp)
    policy.seq_parallel = seq_parallel
    policy.serving = serving
    step = specs.make_step_fn(cfg, shape)
    args, _ = specs.input_specs(cfg, shape)
    in_sh, out_sh, donate = specs.step_shardings(cfg, shape, policy)

    t0 = time.time()
    with mesh, shctx.use_policy(policy):
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = analysis.memory_summary(compiled)
    roof = analysis.analyze(compiled, cfg, shape, len(mesh.devices.flat))
    coll = {"bytes_by_kind": roof.collective_by_kind,
            "counts": roof.collective_counts,
            "total_bytes": roof.collective_bytes}
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "fsdp": fsdp, "seq_parallel": seq_parallel, "serving": serving,
        "status": "ok",
        "mesh": {"shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names)},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": roof.to_dict(),
        "collectives": coll,
    }
    if verbose:
        gb = mem.get("resident_bytes_per_device", 0) / 2**30
        print(f"[dryrun] {arch} x {shape_name} "
              f"mesh={tuple(mesh.devices.shape)} fsdp={fsdp}")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"  memory_analysis: {json.dumps(mem)}")
        print(f"  resident/device: {gb:.2f} GiB "
              f"({'FITS' if gb <= 16 else 'EXCEEDS'} 16 GiB v5e HBM)")
        print(f"  cost_analysis: flops/dev={roof.flops:.3e} "
              f"bytes/dev={roof.hbm_bytes:.3e}")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"-> {roof.dominant}-bound")
        print(f"  useful-FLOPs ratio (model/HLO): "
              f"{roof.useful_flops_ratio:.3f}")
        print(f"  collectives: " + ", ".join(
            f"{k}:{v} ({coll['bytes_by_kind'][k]/2**20:.1f} MiB)"
            for k, v in coll["counts"].items() if v))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--shape", required=True,
                    choices=tuple(configs.INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--serving-layout", dest="serving",
                    action="store_true")
    ap.add_argument("--out", default=None, help="write JSON result here")
    args = ap.parse_args(argv)

    result = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     fsdp=args.fsdp, seq_parallel=args.seq_parallel,
                     serving=args.serving)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    if result["status"] == "skipped":
        print(f"[dryrun] SKIPPED {args.arch} x {args.shape}: "
              f"{result['reason']}")
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
