"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (the kernel bodies execute in Python on CPU; on TPU the same bodies
compile via Mosaic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (chunked_prefill_attention as cpa,
                           decode_attention as fd, flash_attention as fa,
                           paged_decode_attention as pfd,
                           ragged_chunked_prefill as rcp, ref,
                           rmsnorm as rn)

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,H,KV,D,causal,window", [
    (1, 64, 4, 2, 32, True, None),
    (2, 48, 4, 1, 16, True, None),     # MQA + padding (48 % 32 != 0)
    (1, 96, 8, 8, 64, True, 24),       # MHA sliding window
    (1, 32, 2, 2, 128, False, None),   # bidirectional (encoder)
])
def test_flash_attention_sweep(B, S, H, KV, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    out = fa.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,H,KV,D,block_k", [
    (2, 128, 4, 2, 32, 32),
    (1, 100, 8, 1, 64, 64),     # padding (100 % 64)
    (3, 64, 4, 4, 16, 16),
    (1, 512, 8, 2, 128, 128),   # long cache
])
def test_flash_decode_sweep(B, S, H, KV, D, block_k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    mask = jax.random.bernoulli(ks[3], 0.8, (B, S)).at[:, 0].set(True)
    out = fd.flash_decode_attention(q, kc, vc, mask, block_k=block_k,
                                    interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, mask=mask)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,KV,D,block_size,nb", [
    (2, 4, 2, 32, 16, 4),       # GQA, 4-entry tables
    (1, 8, 1, 64, 32, 3),       # MQA
    (3, 4, 4, 16, 64, 2),       # MHA, big pages
    (2, 8, 2, 128, 16, 5),      # long table, wide heads
])
def test_paged_decode_sweep(B, H, KV, D, block_size, nb, dtype):
    """Paged flash-decode vs the block-table gather oracle across block
    sizes and RAGGED per-sequence lengths (tables deliberately permuted
    so physical order != logical order)."""
    N = B * nb + 3               # spare pages: stale/garbage content
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    rng = np.random.default_rng(B * 131 + block_size)
    tables = jnp.asarray(np.stack(
        [rng.permutation(N)[:nb] for _ in range(B)]).astype(np.int32))
    lens = jnp.asarray(
        rng.integers(1, nb * block_size + 1, (B,)).astype(np.int32))
    out = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                           interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    assert out.shape == (B, H, D) and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_paged_decode_matches_contiguous_decode():
    """Triangle closure: a paged cache holding the same logical KV as a
    contiguous cache gives the same attention output (paged ref vs the
    contiguous decode oracle)."""
    B, H, KV, D, bs, nb = 2, 4, 2, 32, 16, 4
    S = nb * bs
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    lens = jnp.asarray([S - 7, 9], jnp.int32)
    # lay the contiguous caches out into per-sequence pages
    kp = kc.reshape(B * nb, bs, KV, D)
    vp = vc.reshape(B * nb, bs, KV, D)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    mask = jnp.arange(S)[None, :] < lens[:, None]
    want = ref.decode_attention_ref(q, kc, vc, mask=mask)
    got = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_kernel = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_empty_row_returns_zeros():
    """A seq_len == 0 row (nothing valid to attend to) must yield zeros,
    not an average of garbage page contents; other rows are unaffected."""
    B, H, KV, D, bs, nb = 2, 4, 2, 32, 16, 3
    N = B * nb
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (N, bs, KV, D))
    vp = jax.random.normal(ks[2], (N, bs, KV, D))
    tables = jnp.arange(N, dtype=jnp.int32).reshape(B, nb)
    lens = jnp.asarray([0, 11], jnp.int32)
    out = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                           interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.zeros((H, D), np.float32))
    want = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(want[1]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,B,H,KV,D,block_size,nb", [
    (16, 2, 4, 2, 32, 16, 4),    # GQA, smallest chunk
    (16, 1, 8, 2, 128, 64, 2),   # wide heads, big pages
    (64, 1, 8, 1, 64, 32, 4),    # MQA, mid chunk
    (128, 2, 4, 4, 16, 16, 12),  # MHA, acceptance chunk sweep top end
])
def test_chunked_prefill_sweep(T, B, H, KV, D, block_size, nb, dtype):
    """Chunked-prefill kernel vs the block-table gather oracle across
    chunk sizes {16, 64, 128} and RAGGED prior-context lengths,
    including the zero-prior-context (first chunk) edge; tables are
    permuted so physical order != logical order."""
    N = B * nb + 3               # spare pages: stale/garbage content
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    rng = np.random.default_rng(T * 7 + B * 131 + block_size)
    tables = jnp.asarray(np.stack(
        [rng.permutation(N)[:nb] for _ in range(B)]).astype(np.int32))
    # row 0 is always the first-chunk edge (zero prior context); others
    # ragged in [0, nb*bs - T]
    maxc = nb * block_size - T
    clens = jnp.asarray(
        [0] + [int(rng.integers(0, maxc + 1)) for _ in range(B - 1)],
        jnp.int32)
    out = cpa.chunked_prefill_attention(q, kp, vp, tables, clens,
                                        interpret=True)
    want = ref.chunked_prefill_attention_ref(q, kp, vp, tables, clens)
    assert out.shape == (B, T, H, D) and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_chunked_prefill_matches_full_causal():
    """Triangle closure: when the pages hold a full sequence and the
    chunk is its tail, chunked-prefill attention equals rows
    [ctx:ctx+T] of ordinary causal attention over the sequence."""
    B, H, KV, D, bs, nb, T = 1, 4, 2, 32, 16, 4, 16
    S = nb * bs
    ctx = S - T
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q_full = jax.random.normal(ks[0], (B, S, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    want = ref.attention_ref(q_full, kc, vc, causal=True)[:, ctx:]
    kp = kc.reshape(B * nb, bs, KV, D)
    vp = vc.reshape(B * nb, bs, KV, D)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    clens = jnp.asarray([ctx], jnp.int32)
    got = ref.chunked_prefill_attention_ref(q_full[:, ctx:], kp, vp,
                                            tables, clens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_kernel = cpa.chunked_prefill_attention(q_full[:, ctx:], kp, vp,
                                               tables, clens,
                                               interpret=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def _ragged_case(lens, ctxs, *, H=4, KV=2, D=32, bs=16, seed=0,
                 dtype=jnp.float32):
    """Build a fused ragged-prefill case: C chunks with the given
    lengths and prior-context lengths, each owning its own permuted
    block table (plus spare garbage pages), queries padded to the
    power-of-two chunk bucket like the engine's packed layout."""
    C = len(lens)
    Tp = 1
    while Tp < max(lens):
        Tp *= 2
    nb = max(-(-(c + l) // bs) for c, l in zip(ctxs, lens)) + 1
    N = C * nb + 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (C, Tp, H, D), jnp.float32).astype(dtype)
    kn = jax.random.normal(ks[1], (C, Tp, KV, D), jnp.float32).astype(dtype)
    vn = jax.random.normal(ks[2], (C, Tp, KV, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[3], (N, bs, KV, D), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[4], (N, bs, KV, D), jnp.float32).astype(dtype)
    rng = np.random.default_rng(seed * 7 + C)
    perm = rng.permutation(N)
    tables = jnp.asarray(perm[:C * nb].reshape(C, nb).astype(np.int32))
    off, meta = 0, []
    for c, (ln, ctx) in enumerate(zip(lens, ctxs)):
        meta.append([c, ctx, ln, off])
        off += ln
    return q, kn, vn, kp, vp, tables, jnp.asarray(meta, jnp.int32)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("lens,ctxs", [
    ([1, 1, 1], [0, 5, 31]),          # single-token chunks
    ([10, 24], [13, 7]),              # chunks crossing page boundaries
    ([16, 8, 4], [0, 0, 0]),          # zero prior context everywhere
    ([32], [9]),                      # one-request degenerate batch
    ([16, 64, 128, 64, 16], [3, 0, 40, 16, 128]),  # mixed {16,64,128}
])
def test_ragged_chunked_prefill_sweep(lens, ctxs, dtype):
    """Fused ragged kernel vs the jnp oracle: attention output on every
    VALID row (rows past chunk_len are undefined padding) and the page
    pools — the in-kernel scatter must match the oracle's drop-mode
    packed scatter bit for bit."""
    q, kn, vn, kp, vp, tables, meta = _ragged_case(lens, ctxs, dtype=dtype)
    out, nk, nv = rcp.ragged_chunked_prefill(q, kn, vn, kp, vp, tables,
                                             meta, interpret=True)
    want, wk, wv = ref.ragged_chunked_prefill_ref(q, kn, vn, kp, vp,
                                                  tables, meta)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(wv))
    assert out.shape == q.shape and out.dtype == dtype
    for c, ln in enumerate(lens):
        np.testing.assert_allclose(
            np.asarray(out[c, :ln]).astype(np.float32),
            np.asarray(want[c, :ln]).astype(np.float32), **_tol(dtype))


def test_ragged_matches_per_chunk_kernel():
    """Triangle closure: one fused launch over C chunks equals C
    separate ``chunked_prefill_attention`` launches run after a
    separate scatter pass (same pages, same masks)."""
    lens, ctxs = [16, 64, 128], [5, 0, 30]
    q, kn, vn, kp, vp, tables, meta = _ragged_case(lens, ctxs, seed=3)
    out, nk, nv = rcp.ragged_chunked_prefill(q, kn, vn, kp, vp, tables,
                                             meta, interpret=True)
    # per-chunk reference: scatter each chunk, then run the per-chunk
    # kernel against the post-scatter pages
    _, sk, sv = ref.ragged_chunked_prefill_ref(q, kn, vn, kp, vp,
                                               tables, meta)
    for c, ln in enumerate(lens):
        got_c = cpa.chunked_prefill_attention(
            q[c:c + 1, :ln], sk, sv, tables[c:c + 1],
            meta[c:c + 1, 1], interpret=True)
        np.testing.assert_allclose(np.asarray(out[c, :ln]),
                                   np.asarray(got_c[0]),
                                   atol=2e-5, rtol=2e-5)


def test_ragged_padding_chunk_writes_nothing():
    """A padding chunk (chunk_len == 0, trash-only table — the engine's
    contract: a scattered page is never revisited by another chunk)
    must leave every page bit-identical and not disturb its batch
    siblings."""
    lens, ctxs = [8, 4], [0, 16]
    q, kn, vn, kp, vp, tables, meta = _ragged_case(lens, ctxs, seed=5)
    # append a padding chunk whose table points only at a spare (trash)
    # page no real chunk owns, exactly as the engine builds it
    meta_pad = jnp.concatenate(
        [meta, jnp.asarray([[2, 0, 0, 12]], jnp.int32)])
    # _ragged_case keeps 3 spare pages; pick one no chunk's table uses
    spare = (set(range(kp.shape[0])) - set(np.asarray(tables).ravel()
                                           .tolist())).pop()
    tables_pad = jnp.concatenate(
        [tables, jnp.full_like(tables[:1], spare)])
    q3 = jnp.concatenate([q, q[:1]])
    kn3 = jnp.concatenate([kn, kn[:1]])
    vn3 = jnp.concatenate([vn, vn[:1]])
    out3, nk3, nv3 = rcp.ragged_chunked_prefill(
        q3, kn3, vn3, kp, vp, tables_pad, meta_pad, interpret=True)
    out, nk, nv = rcp.ragged_chunked_prefill(q, kn, vn, kp, vp, tables,
                                             meta, interpret=True)
    np.testing.assert_array_equal(np.asarray(nk3), np.asarray(nk))
    np.testing.assert_array_equal(np.asarray(nv3), np.asarray(nv))
    for c, ln in enumerate(lens):
        np.testing.assert_array_equal(np.asarray(out3[c, :ln]),
                                      np.asarray(out[c, :ln]))


def test_ops_ragged_wrapper_dispatch():
    """ops.ragged_chunked_prefill: kernel (interpret) vs oracle path."""
    from repro.kernels import ops
    lens, ctxs = [4, 16], [0, 9]
    q, kn, vn, kp, vp, tables, meta = _ragged_case(lens, ctxs, seed=11)
    a_out, a_k, a_v = ops.ragged_chunked_prefill(
        q, kn, vn, kp, vp, tables, meta, use_pallas=True, interpret=True)
    b_out, b_k, b_v = ops.ragged_chunked_prefill(
        q, kn, vn, kp, vp, tables, meta, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(b_k))
    np.testing.assert_array_equal(np.asarray(a_v), np.asarray(b_v))
    for c, ln in enumerate(lens):
        np.testing.assert_allclose(np.asarray(a_out[c, :ln]),
                                   np.asarray(b_out[c, :ln]),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape,block_rows", [
    ((8, 128), 4), ((3, 5, 256), 8), ((17, 64), 8), ((1, 1024), 1),
])
def test_rmsnorm_sweep(shape, block_rows, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    w = (jax.random.normal(key, shape[-1:], jnp.float32) * 0.2).astype(dtype)
    out = rn.rms_norm(x, w, block_rows=block_rows, interpret=True)
    want = ref.rms_norm_ref(x, w)
    assert out.shape == x.shape and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_ops_wrappers_dispatch():
    """use_pallas=False falls back to the layers implementations."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    a = ops.flash_attention(q, k, v, use_pallas=True, interpret=True,
                            block_q=16, block_k=16)
    b = ops.flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
    x = jax.random.normal(ks[0], (4, 64))
    w = jnp.zeros(64)
    np.testing.assert_allclose(
        ops.rms_norm(x, w, use_pallas=True, interpret=True),
        ops.rms_norm(x, w, use_pallas=False), atol=1e-5, rtol=1e-5)
    qd = jax.random.normal(ks[0], (2, 4, 16))
    kp = jax.random.normal(ks[1], (6, 8, 2, 16))
    vp = jax.random.normal(ks[2], (6, 8, 2, 16))
    tables = jnp.asarray([[0, 2, 4], [1, 3, 5]], jnp.int32)
    lens = jnp.asarray([17, 9], jnp.int32)
    np.testing.assert_allclose(
        ops.paged_decode_attention(qd, kp, vp, tables, lens,
                                   use_pallas=True, interpret=True),
        ops.paged_decode_attention(qd, kp, vp, tables, lens,
                                   use_pallas=False),
        atol=1e-4, rtol=1e-4)
    qc = jax.random.normal(ks[0], (2, 8, 4, 16))
    clens = jnp.asarray([0, 9], jnp.int32)
    np.testing.assert_allclose(
        ops.chunked_prefill_attention(qc, kp, vp, tables, clens,
                                      use_pallas=True, interpret=True),
        ops.chunked_prefill_attention(qc, kp, vp, tables, clens,
                                      use_pallas=False),
        atol=1e-4, rtol=1e-4)
