"""Batched generation on top of model.prefill / model.decode_step.

Two drivers:
  * ``generate()`` — host-loop greedy decoding with early exit when every
    sequence hit EOS (used by the serving engine; the host loop is what a
    real-time scheduler interleaves with queue management).
  * ``generate_scan()`` — fully-jitted lax.scan decode for a fixed number
    of steps (used by benchmarks; no host round-trips).
"""

from __future__ import annotations

import functools
import logging
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model as model_lib, transformer

PAD_ID = 0

logger = logging.getLogger(__name__)
_warned_jnp_fallback = False


def resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """Resolve the ``use_pallas=None`` auto-detection: the compiled
    Pallas kernels on TPU, the exact jnp fallbacks elsewhere (the
    kernels would run in slow interpret mode).  Logs a ONE-TIME warning
    when auto-detection falls back to the jnp path, so silent CPU
    fallbacks are visible in benchmark runs."""
    global _warned_jnp_fallback
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
        if not use_pallas and not _warned_jnp_fallback:
            _warned_jnp_fallback = True
            logger.warning(
                "use_pallas auto-detection: backend %r is not TPU — "
                "falling back to the exact jnp kernel paths (pass "
                "use_pallas=True to force the Pallas kernels in "
                "interpret mode)", jax.default_backend())
    return use_pallas


def make_prefill_fn(cfg, max_len: int):
    @functools.partial(jax.jit, static_argnames=())
    def prefill_fn(params, batch):
        return model_lib.prefill(params, cfg, batch, max_len)

    return prefill_fn


def make_decode_fn(cfg):
    @jax.jit
    def decode_fn(params, cache, token):
        return model_lib.decode_step(params, cfg, cache, token)

    return decode_fn


def make_slot_prefill_fn(cfg, max_len: int):
    """Jitted continuous-batching admission: prefill one (1, S) request
    into slot ``slot`` of a per-slot decode cache.  The slot index is a
    traced operand, so ONE executable serves every slot."""
    @jax.jit
    def slot_prefill_fn(params, cache, batch, slot):
        return model_lib.prefill_into_slot(params, cfg, cache, batch,
                                           slot, max_len)

    return slot_prefill_fn


def make_paged_prefill_fn(cfg, max_len: int):
    """Jitted paged admission: prefill one (1, S) request into the page
    pool at the blocks named by ``table_row``.  Slot index and table
    are traced operands, so ONE executable serves every admission."""
    @jax.jit
    def paged_prefill_fn(params, cache, batch, slot, table_row):
        return model_lib.prefill_into_paged(params, cfg, cache, batch,
                                            slot, table_row, max_len)

    return paged_prefill_fn


def make_paged_decode_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted paged decode step; block tables ride as a per-call operand
    (the engine extends them host-side on block-boundary crossings).

    use_pallas: route attention through the Pallas
    ``paged_decode_attention`` kernel (no transient contiguous gather).
    ``None`` auto-selects: on TPU the compiled kernel, elsewhere the
    exact jnp gather fallback (the kernel would run in slow interpret
    mode there)."""
    use_pallas = resolve_use_pallas(use_pallas)

    @jax.jit
    def paged_decode_fn(params, cache, token, tables):
        return model_lib.decode_step_paged(params, cfg, cache, token,
                                           tables, use_pallas=use_pallas)

    return paged_decode_fn


_chunk_fn_memo: dict = {}


def _memoized(key, build):
    """Process-wide factory memo: engines sharing a (hashable) key
    reuse ONE jitted function — and therefore one trace cache — so
    per-shape executables compile once per process instead of once per
    engine instance.  An unhashable key skips the memo."""
    try:
        cached = _chunk_fn_memo.get(key)
    except TypeError:                      # unhashable cfg: no memo
        return build()
    if cached is None:
        cached = _chunk_fn_memo[key] = build()
    return cached


def make_chunk_prefill_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted chunked-prefill step: run one (1, T) prompt chunk of slot
    ``slot`` against the paged cache at traced context offset
    ``ctx_len``, scattering its K/V through ``table_row``.  Slot, table
    and offset are traced operands, so ONE executable serves every
    chunk of every request (one retrace per distinct chunk length).
    Memoized per ``(cfg, use_pallas)``."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @jax.jit
        def chunk_prefill_fn(params, cache, batch, slot, table_row,
                             ctx_len):
            return model_lib.prefill_chunk(params, cfg, cache, batch,
                                           slot, table_row, ctx_len,
                                           use_pallas=use_pallas)

        return chunk_prefill_fn

    return _memoized((cfg, use_pallas), build)


def make_ragged_prefill_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted FUSED chunked prefill: every scheduled chunk of one
    engine iteration in a single launch (``model.prefill_chunks``).

    The packed token stream, per-token chunk ids, metadata rows
    ``[slot, ctx_len, chunk_len, q_offset]`` and per-chunk block
    tables all ride as traced operands; ``chunk_pad`` (the padded
    per-chunk view width) is static.  jit therefore memoizes one
    executable per padded shape key ``(padded_tokens, padded_chunks,
    padded_chunk_len)`` — the ``ChunkBatch.shape_key`` buckets —
    instead of retracing per ``(chunk_len, offset)`` pair.  Memoized
    per ``(cfg, use_pallas)`` like ``make_chunk_prefill_fn``."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @functools.partial(jax.jit, static_argnames=("chunk_pad",))
        def ragged_prefill_fn(params, cache, batch, token_chunk, meta,
                              tables, *, chunk_pad):
            return model_lib.prefill_chunks(params, cfg, cache, batch,
                                            token_chunk, meta, tables,
                                            chunk_pad=chunk_pad,
                                            use_pallas=use_pallas)

        return ragged_prefill_fn

    return _memoized(("ragged", cfg, use_pallas), build)


def make_copy_block_fn(cfg):
    """Jitted copy-on-write page copy: duplicate physical block ``src``
    into ``dst`` across every layer's page pools (the prefix cache's
    full-match admission).  ``src``/``dst`` ride as traced operands, so
    ONE executable serves every CoW copy."""
    del cfg  # the cache pytree fixes every shape

    @jax.jit
    def copy_block_fn(cache, src, dst):
        return transformer.copy_paged_block(cache, src, dst)

    return copy_block_fn


def generate(params, cfg, batch: dict, *, max_new_tokens: int,
             eos_id: int = 1, prefill_fn=None, decode_fn=None,
             max_lens=None):
    """Greedy-decode a batch. Returns (tokens (B, T<=max_new), lengths).

    max_lens: optional (B,) per-sequence output-length caps — a sequence
    stops contributing once it has produced its cap, but the batch keeps
    stepping until its LONGEST member finishes (the head-of-line effect
    run-to-completion batching suffers from, and the baseline the
    continuous-batching engine is measured against).
    """
    max_len = batch["tokens"].shape[1] + max_new_tokens + 8
    if cfg.frontend == "vision":
        max_len += cfg.num_patch_tokens
    prefill_fn = prefill_fn or make_prefill_fn(cfg, max_len)
    decode_fn = decode_fn or make_decode_fn(cfg)

    cache, last_logits = prefill_fn(params, batch)
    B = batch["tokens"].shape[0]
    token = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    done = (token[:, 0] == eos_id)
    lengths = jnp.ones((B,), jnp.int32)
    if max_lens is not None:
        max_lens = jnp.asarray(max_lens, jnp.int32)
        done = done | (lengths >= max_lens)
    out = [token]
    for _ in range(max_new_tokens - 1):
        if bool(done.all()):
            break
        token, _, cache = decode_fn(params, cache, token)
        token = jnp.where(done[:, None], PAD_ID, token)
        lengths = lengths + (~done).astype(jnp.int32)
        done = done | (token[:, 0] == eos_id)
        if max_lens is not None:
            done = done | (lengths >= max_lens)
        out.append(token)
    return jnp.concatenate(out, axis=1), lengths


def generate_scan(params, cfg, batch: dict, *, max_new_tokens: int):
    """Fixed-length jitted decode (benchmarks / dry-run style)."""
    max_len = batch["tokens"].shape[1] + max_new_tokens + 8
    if cfg.frontend == "vision":
        max_len += cfg.num_patch_tokens

    @jax.jit
    def run(params, batch):
        cache, last_logits = model_lib.prefill(params, cfg, batch, max_len)
        token = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]

        def body(carry, _):
            token, cache = carry
            nt, _, cache = model_lib.decode_step(params, cfg, cache, token)
            return (nt, cache), token

        (_, _), tokens = lax.scan(
            body, (token, cache), None, length=max_new_tokens)
        return tokens[:, :, 0].T                       # (B, T)

    return run(params, batch)
