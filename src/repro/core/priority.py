"""Priority assignment (paper §IV-B, Eq. 2 / Eq. 3).

Notation per the paper: for task J with arrival r_J, priority point d_J,
uncertainty score u_J (predicted output length, tokens) and per-model
coefficients eta_f (s/output-token), phi_f (s/input-token):

  d_J   = r_J + phi_f * |J|      (empirical priority point; a
                                  user-specified deadline t_J replaces it)
  Eq. 2: p_J = 1 / (d_J - r_J - eta_f * u_J)                       (slack)
  Eq. 3: p_J = (1 - alpha * u_hat_J) / (d_J - r_J - eta_f * u_J)   (UP)

Normalization note (recorded in DESIGN.md §6): the paper sweeps alpha in
[0, 2] and calls alpha*u a "scaled uncertainty score"; with u in raw token
units (tens) the numerator would be dominated by -alpha*u for any alpha.
We therefore scale u_hat = u / u_scale (u_scale = a high quantile of the
training-set scores) inside Eq. 3, keeping raw token units everywhere
else (consolidation ratios, offload threshold tau).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

_EPS = 1e-6


def priority_point(arrival: float, input_len: float, phi: float,
                   deadline: Optional[float] = None,
                   xi: float = 2.0) -> float:
    """d_J: user deadline if present, else arrival + xi + phi_f * |J|.

    Adaptation note (DESIGN.md §6): the system-level batching window xi
    is added to the empirical priority point so that an unloaded system
    can actually meet it — with d = r + phi|J| alone every task would
    miss by construction, since dispatch waits up to xi for batch mates.
    """
    if deadline is not None:
        return deadline
    return arrival + xi + phi * input_len


def slack(d: float, r: float, u: float, eta: float) -> float:
    return d - r - eta * u


def eq2_priority(d: float, r: float, u: float, eta: float) -> float:
    """Eq. 2 — pure slack-based priority."""
    s = slack(d, r, u, eta)
    if abs(s) < _EPS:
        s = _EPS
    return 1.0 / s


def eq3_priority(d: float, r: float, u: float, eta: float, alpha: float,
                 u_scale: float) -> float:
    """Eq. 3 — Uncertainty-aware Prioritization (UP)."""
    s = slack(d, r, u, eta)
    if abs(s) < _EPS:
        s = _EPS
    u_hat = u / max(u_scale, _EPS)
    return (1.0 - alpha * u_hat) / s


@dataclasses.dataclass
class SimTask:
    """A task as seen by the scheduler: prediction + timing metadata."""
    task: object              # datagen.Task
    u: float                  # predicted uncertainty score (tokens)
    r: float                  # arrival time (s)
    d: float                  # priority point (s)
    input_len: float
    true_out_len: int         # persona ground truth (hidden from policy)
    u_hi: float = -1.0        # tail (P90) prediction; -1 -> mirror u
    p: float = 0.0            # assigned priority
    # filled by the simulator:
    start: float = -1.0
    finish: float = -1.0
    lane: str = ""

    def __post_init__(self):
        if self.u_hi < 0:
            self.u_hi = self.u

    @property
    def response_time(self) -> float:
        return self.finish - self.r

    @property
    def missed(self) -> bool:
        return self.finish > self.d
