"""Hypothesis property tests over system invariants.

Optional dev dependency: the whole module skips when `hypothesis` is not
installed (see requirements-dev.txt) so the suite still collects on
minimal environments; the deterministic seeded versions of the simulator
invariants live in tests/test_simulator.py and always run.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (personas, priority as prio, rulegen,  # noqa: E402
                        scheduler as sched, simulator, workload)
from repro.kvcache import (BlockAllocator, PrefixCache,  # noqa: E402
                           blocks_for_tokens)
from repro.kvcache.allocator import OutOfBlocksError  # noqa: E402
from repro.kvcache.paged import (gather_tokens,  # noqa: E402
                                 scatter_prefill, scatter_token)
from repro.models import transformer  # noqa: E402
from repro.prefill import ChunkScheduler  # noqa: E402
from repro.serving.engine import hash_tokenize  # noqa: E402

text_strategy = st.text(
    alphabet=st.characters(codec="ascii"), min_size=0, max_size=300)


@settings(max_examples=80, deadline=None)
@given(text=text_strategy)
def test_rulegen_total_on_arbitrary_text(text):
    """RULEGEN never crashes and always returns finite non-negative
    intensities — it sits on the request hot path."""
    r = rulegen.rulegen(text)
    assert r.shape == (6,)
    assert np.isfinite(r).all()
    assert (r >= 0).all()
    f = rulegen.features(text)
    assert f.shape == (rulegen.FEATURE_DIM,)
    assert np.isfinite(f).all()
    s = rulegen.single_rule_score(text)
    assert np.isfinite(s) and s >= 0


@settings(max_examples=40, deadline=None)
@given(text=text_strategy, vocab=st.integers(10, 50000),
       max_len=st.integers(1, 64))
def test_hash_tokenize_in_range(text, vocab, max_len):
    toks = hash_tokenize(text, vocab, max_len)
    assert 1 <= len(toks) <= max(max_len, 1)
    assert all(2 <= t < vocab for t in toks)


@settings(max_examples=40, deadline=None)
@given(cap=st.integers(1, 64), seq=st.integers(0, 200))
def test_prefill_slot_pos_invariants(cap, seq):
    """Ring-buffer slot map: every kept position is one of the last `cap`
    prefilled positions, each exactly once, at slot pos % cap."""
    sp = np.asarray(transformer.prefill_slot_pos(cap, seq))
    assert sp.shape == (cap,)
    kept = sp[sp < 2 ** 29]
    expect = np.arange(max(0, seq - cap), seq)
    assert sorted(kept.tolist()) == expect.tolist()
    for pos in kept:
        assert sp[pos % cap] == pos


PERSONA = personas.get_persona("dialogpt")


def _sim_tasks(us, arrivals):
    return [prio.SimTask(task=None, u=float(u), r=float(r),
                         d=float(r) + 4.0, input_len=5.0,
                         true_out_len=max(1, int(u)))
            for u, r in zip(us, arrivals)]


@settings(max_examples=25, deadline=None)
@given(
    us=st.lists(st.floats(0.5, 60.0), min_size=1, max_size=60),
    seed=st.integers(0, 10),
    policy=st.sampled_from(["fifo", "hpf", "luf", "muf", "up", "up+c",
                            "rt-lm"]),
    mode=st.sampled_from(["batch", "continuous"]),
)
def test_simulation_invariants(us, seed, policy, mode):
    """No task lost or duplicated; response >= service; finite makespan —
    in BOTH execution models."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.3, len(us)))
    tasks = _sim_tasks(us, arrivals)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    res = simulator.run_policy(tasks, policy, PERSONA, pcfg, mode=mode)
    assert len(res.tasks) == len(us)                    # conservation
    ids = sorted(id(t) for t in res.tasks)
    assert len(set(ids)) == len(ids)                    # no duplication
    for t in res.tasks:
        assert t.finish >= t.start >= 0
        assert t.start + 1e-9 >= t.r                    # causality
    assert np.isfinite(res.makespan)


@settings(max_examples=10, deadline=None)
@given(beta=st.integers(10, 300), n=st.integers(5, 80),
       seed=st.integers(0, 5))
def test_poisson_trace_properties(beta, n, seed):
    arr = workload.constant_rate_trace(n, beta, seed)
    assert len(arr) == n
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    assert arr[0] >= 0


@settings(max_examples=25, deadline=None)
@given(out_len=st.integers(3, 20), n=st.integers(1, 50),
       rate=st.floats(0.01, 2.0), seed=st.integers(0, 10))
def test_continuous_no_regression_homogeneous_fifo(out_len, n, rate, seed):
    """Hypothesis form of the no-regression property (deterministic
    sweep in tests/test_continuous.py): on homogeneous output lengths
    under FIFO, continuous batching never increases ANY request's
    response time vs run-to-completion batching.

    out_len >= 3 on purpose: 1-2-token (prefill-dominated) sequences
    are degenerate for iteration-level batching — the slot is occupied
    for <= 1 decode step, so every admission is an idle restart paying
    setup_time, while run-to-completion amortizes one setup over the
    whole flush-formed batch.  That regime regresses by design in both
    the simulator and the real engine."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(rate, n))
    tasks = [prio.SimTask(task=i, u=5.0, r=float(r), d=float(r) + 4.0,
                          input_len=5.0, true_out_len=out_len)
             for i, r in enumerate(arrivals)]
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    rtc = simulator.run_policy(tasks, "fifo", PERSONA, pcfg, mode="batch")
    cont = simulator.run_policy(tasks, "fifo", PERSONA, pcfg,
                                mode="continuous")
    rt_batch = {t.task: t.response_time for t in rtc.tasks}
    rt_cont = {t.task: t.response_time for t in cont.tasks}
    assert set(rt_batch) == set(rt_cont)
    for i in rt_batch:
        assert rt_cont[i] <= rt_batch[i] + 1e-9


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(1, 32),
       commands=st.lists(
           st.tuples(st.sampled_from(["alloc", "free"]),
                     st.integers(0, 5)),
           max_size=60))
def test_allocator_never_double_allocates(num_blocks, commands):
    """kvcache.BlockAllocator: a live block is owned by exactly one
    sequence at every point of an arbitrary alloc/free interleaving,
    accounting always balances, and frees are complete (no leaks)."""
    a = BlockAllocator(num_blocks, 16)
    live = {}                                 # seq -> set(blocks)
    for op, seq in commands:
        if op == "alloc":
            if a.num_free == 0:
                with pytest.raises(OutOfBlocksError):
                    a.allocate(seq)
                continue
            blk = a.allocate(seq)
            for blocks in live.values():
                assert blk not in blocks, "double-allocated live block"
            live.setdefault(seq, set()).add(blk)
        else:
            freed = a.free_sequence(seq)
            assert freed == len(live.pop(seq, set()))
        assert a.num_free + a.num_used == num_blocks
        assert a.num_used == sum(len(b) for b in live.values())
    for seq in list(live):
        a.free_sequence(seq)
    a.check_no_leaks()


@settings(max_examples=40, deadline=None)
@given(num_blocks=st.integers(2, 24), data=st.data())
def test_refcount_sharing_and_cow_never_corrupt_readers(num_blocks, data):
    """kvcache.BlockAllocator refcounts (ISSUE 4): under arbitrary
    interleavings of allocate / share / write / free, (1) no block
    still referenced by any sequence is ever freed, and (2) a write to
    a shared block goes through copy-on-write and never changes what
    any OTHER holder reads.  ``content`` shadows each physical block's
    value; ``view`` is what each sequence must keep reading."""
    a = BlockAllocator(num_blocks, 16)
    content = {}                       # block -> last written value
    view = {}                          # seq -> values it must read
    tables = {}                        # seq -> mirror of a.table(seq)
    val = 0
    for _ in range(data.draw(st.integers(1, 60))):
        op = data.draw(st.sampled_from(["alloc", "share", "write",
                                        "free"]))
        seq = data.draw(st.integers(0, 5))
        if op == "alloc":
            if a.num_free == 0:
                continue
            val += 1
            blk = a.allocate(seq)
            content[blk] = val
            tables.setdefault(seq, []).append(blk)
            view.setdefault(seq, []).append(val)
        elif op == "share":
            donors = [s for s, t in tables.items() if t and s != seq]
            if not donors:
                continue
            d = data.draw(st.sampled_from(donors))
            i = data.draw(st.integers(0, len(tables[d]) - 1))
            blk = tables[d][i]
            a.share(seq, blk)
            tables.setdefault(seq, []).append(blk)
            view.setdefault(seq, []).append(content[blk])
        elif op == "write":
            holders = [s for s, t in tables.items() if t]
            if not holders:
                continue
            s2 = data.draw(st.sampled_from(holders))
            i = data.draw(st.integers(0, len(tables[s2]) - 1))
            blk = tables[s2][i]
            val += 1
            if a.refcount(blk) > 1:    # divergent write -> CoW
                if a.num_free == 0:
                    continue
                src, dst = a.cow_block(s2, i)
                assert src == blk and a.refcount(dst) == 1
                content[dst] = val     # copy + write the private copy
                tables[s2][i] = dst
            else:
                content[blk] = val     # private block: write in place
            view[s2][i] = val
        else:
            tables.pop(seq, None)
            view.pop(seq, None)
            a.free_sequence(seq)
        assert a.num_free + a.num_used == num_blocks
        for s, t in tables.items():
            for i, blk in enumerate(t):
                assert a.refcount(blk) >= 1, "freed a referenced block"
                assert content[blk] == view[s][i], \
                    "a write became visible to another reader"
    for s in list(tables):
        a.free_sequence(s)
    a.check_no_leaks()


@settings(max_examples=40, deadline=None)
@given(bs=st.integers(1, 4), num_blocks=st.integers(4, 24),
       data=st.data())
def test_prefix_cache_admit_commit_invariants(bs, num_blocks, data):
    """kvcache.PrefixCache over random prompts from a tiny alphabet
    (forcing prefix collisions): matches are block-aligned longest
    prefixes that leave at least one position to recompute, tables are
    complete, eviction only fires under pressure, and after all
    sequences die a ``clear()`` makes the pool whole."""
    a = BlockAllocator(num_blocks, bs)
    pc = PrefixCache(a, bs)
    live = []
    seq = 0
    for _ in range(data.draw(st.integers(1, 25))):
        if live and data.draw(st.booleans()):
            a.free_sequence(live.pop(data.draw(
                st.integers(0, len(live) - 1))))
            continue
        S = data.draw(st.integers(1, 2 * bs + 2))
        toks = data.draw(st.lists(st.integers(0, 2), min_size=S,
                                  max_size=S))
        if blocks_for_tokens(S, bs) > num_blocks:
            continue
        try:
            adm = pc.admit(seq, toks)
        except OutOfBlocksError:
            a.free_sequence(seq)       # drop any partially shared refs
            seq += 1
            continue                   # pool genuinely exhausted
        assert 0 <= adm.start <= max(S - 1, 0)
        assert adm.start < S           # >= 1 position always recomputed
        assert len(a.table(seq)) == blocks_for_tokens(S, bs)
        assert len(adm.cow) == (1 if adm.matched_blocks * bs == S else 0)
        pc.commit(seq, toks)
        live.append(seq)
        seq += 1
    for s in live:
        a.free_sequence(s)
    pc.clear()
    a.check_no_leaks()


@settings(max_examples=30, deadline=None)
@given(bs=st.integers(1, 16), nb=st.integers(1, 6),
       spare=st.integers(0, 4), data=st.data())
def test_page_gather_roundtrips_writes(bs, nb, spare, data):
    """kvcache paging: block-table gather round-trips
    scatter_prefill/scatter_token contents for every (block_size, table
    length, ragged sequence length) combination."""
    S = data.draw(st.integers(1, nb * bs))
    N = nb + spare
    rng = np.random.default_rng(S * 31 + bs)
    table = jnp.asarray(rng.permutation(N)[:nb].astype(np.int32))
    seq = jnp.asarray(rng.normal(size=(S, 3)).astype(np.float32))
    pages = jnp.asarray(rng.normal(size=(N, bs, 3)).astype(np.float32))
    n_prefill = S // 2
    if n_prefill:
        pages = scatter_prefill(pages, seq[:n_prefill], table, n_prefill)
    for pos in range(n_prefill, S):
        pages = scatter_token(pages, seq[pos][None], table[None, :],
                              jnp.asarray([pos]))
    got = gather_tokens(pages, table[None, :])[0, :S]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(seq))


@settings(max_examples=25, deadline=None)
@given(
    us=st.lists(st.floats(0.5, 60.0), min_size=1, max_size=40),
    seed=st.integers(0, 10),
    policy=st.sampled_from(["fifo", "hpf", "rt-lm"]),
    bs=st.integers(1, 8),
    headroom=st.integers(0, 24),
)
def test_block_budget_sim_invariants(us, seed, policy, bs, headroom):
    """simulate_continuous with the block-budget admission model: no
    task lost/duplicated, reservations never exceed the budget, and the
    whole trace still completes (reservation admission is deadlock-free
    by construction)."""
    prompt = 8
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.3, len(us)))
    tasks = _sim_tasks(us, arrivals)
    worst = max(blocks_for_tokens(prompt + max(1, t.true_out_len) - 1, bs)
                for t in tasks)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    pol = sched.POLICIES[policy](PERSONA, pcfg)
    res = simulator.simulate_continuous(
        tasks, pol, num_slots=4, kv_block_size=bs,
        kv_num_blocks=worst + headroom, prompt_len=prompt)
    assert len(res.tasks) == len(us)
    ids = sorted(id(t) for t in res.tasks)
    assert len(set(ids)) == len(ids)
    assert 0.0 <= res.kv_util_mean <= res.kv_util_peak <= 1.0 + 1e-9
    assert res.peak_concurrency <= 4


@settings(max_examples=60, deadline=None)
@given(
    chunk=st.integers(1, 16),
    headroom=st.integers(0, 32),
    totals=st.lists(st.integers(1, 64), min_size=1, max_size=12),
    priorities=st.lists(st.floats(-10.0, 10.0), min_size=12, max_size=12),
    decode_loads=st.lists(st.integers(0, 24), min_size=1, max_size=200),
)
def test_chunk_scheduler_invariants(chunk, headroom, totals, priorities,
                                    decode_loads):
    """repro.prefill.ChunkScheduler: (1) scheduled chunk tokens never
    exceed max(0, budget - decode_tokens) in ANY iteration; (2) each
    job's chunks are scheduled at strictly increasing offsets covering
    [0, total) exactly; (3) work conservation — an iteration with
    pending jobs and a whole chunk of headroom schedules at least one
    chunk, so no job starves (bounded wait)."""
    budget = chunk + headroom
    s = ChunkScheduler(chunk, budget)
    for j, total in enumerate(totals):
        s.add(j, slot=j, total=total, priority=priorities[j])
    covered = {j: 0 for j in range(len(totals))}

    def one_iteration(decode):
        had_jobs = s.has_jobs
        plans = s.schedule(decode)
        assert sum(p.length for p in plans) <= max(0, budget - decode)
        if had_jobs and max(0, budget - decode) >= chunk:
            assert plans, "starved with pending work and headroom"
        for p in plans:
            assert p.start == covered[p.job.task]      # in order, no gaps
            assert 1 <= p.length <= chunk
            covered[p.job.task] += p.length
            assert p.finishes == (covered[p.job.task]
                                  == totals[p.job.task])

    # arbitrary (possibly budget-exceeding) decode loads first ...
    for decode in decode_loads:
        if not s.has_jobs:
            break
        one_iteration(decode)
    # ... then drain with an idle decode loop (work conservation
    # guarantees one chunk per iteration, so this terminates)
    drain = 0
    while s.has_jobs:
        one_iteration(0)
        drain += 1
        assert drain <= sum(totals)
    assert covered == {j: t for j, t in enumerate(totals)}


@settings(max_examples=40, deadline=None)
@given(
    chunk=st.integers(1, 8),
    n_jobs=st.integers(1, 10),
    total=st.integers(1, 32),
    decode=st.integers(0, 8),
)
def test_chunk_scheduler_fifo_no_starvation(chunk, n_jobs, total, decode):
    """Under equal priorities (FIFO tie-break) jobs COMPLETE prefill in
    admission order and the whole backlog drains within the obvious
    token bound."""
    budget = chunk + decode           # always one chunk of headroom
    s = ChunkScheduler(chunk, budget)
    for j in range(n_jobs):
        s.add(j, slot=j, total=total, priority=0.0)
    finish_order = []
    iters = 0
    while s.has_jobs:
        for p in s.schedule(decode):
            if p.finishes:
                finish_order.append(p.job.task)
        iters += 1
    assert finish_order == list(range(n_jobs))
    # bounded wait: one whole chunk per iteration is guaranteed
    assert iters <= n_jobs * total


@settings(max_examples=15, deadline=None)
@given(
    us=st.lists(st.floats(0.5, 60.0), min_size=1, max_size=30),
    seed=st.integers(0, 10),
    policy=st.sampled_from(["fifo", "hpf", "rt-lm"]),
    chunk=st.integers(1, 8),
    headroom=st.integers(0, 16),
)
def test_chunked_sim_invariants(us, seed, policy, chunk, headroom):
    """simulate_continuous(prefill="chunked"): no task lost or
    duplicated, every budget-trace entry respects the token budget,
    and the tail-latency percentiles are ordered."""
    prompt = 16
    budget = chunk + headroom
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.3, len(us)))
    tasks = _sim_tasks(us, arrivals)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    pol = sched.POLICIES[policy](PERSONA, pcfg)
    res = simulator.simulate_continuous(
        tasks, pol, num_slots=4, prompt_len=prompt,
        prefill="chunked", chunk_size=chunk, token_budget=budget)
    assert len(res.tasks) == len(us)
    ids = sorted(id(t) for t in res.tasks)
    assert len(set(ids)) == len(ids)
    for decode_toks, prefill_toks in res.budget_trace:
        assert 0 <= decode_toks <= 4
        assert prefill_toks <= max(0, budget - decode_toks)
    assert res.ttft_p50 <= res.ttft_p99 + 1e-9
    assert res.itl_p50 <= res.itl_p99 + 1e-9


@settings(max_examples=30, deadline=None)
@given(cap=st.integers(1, 32), seq=st.integers(1, 80),
       extra=st.integers(1, 40))
def test_ring_cache_decode_continuation(cap, seq, extra):
    """Writing tokens one-by-one after prefill keeps the slot map exactly
    consistent with a fresh prefill of the longer sequence."""
    sp = jnp.asarray(transformer.prefill_slot_pos(cap, seq))
    for pos in range(seq, seq + extra):
        sp = sp.at[pos % cap].set(pos)
    want = transformer.prefill_slot_pos(cap, seq + extra)
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(want))


# ---------------------------------------------------------------------------
# multi-replica router invariants (PR 9) — deterministic mirrors live in
# tests/test_router.py and always run
# ---------------------------------------------------------------------------


def _classed_sim_tasks(us, arrivals, classes):
    import types
    return [prio.SimTask(
        task=types.SimpleNamespace(task_id=i, traffic_class=classes[i]),
        u=float(u), r=float(r), d=float(r) + 4.0, input_len=5.0,
        true_out_len=max(1, int(u)))
        for i, (u, r) in enumerate(zip(us, arrivals))]


@settings(max_examples=25, deadline=None)
@given(
    us=st.lists(st.floats(0.5, 30.0), min_size=1, max_size=40),
    seed=st.integers(0, 10),
    R=st.integers(1, 5),
    rpolicy=st.sampled_from(["round_robin", "least_queue", "rtlm"]),
    bulk=st.booleans(),
)
def test_router_conservation(us, seed, R, rpolicy, bulk):
    """simulate_replicated places every request on exactly one replica
    inside its eligibility set, loses and duplicates nothing, and the
    bulk slice never hosts interactive traffic."""
    from repro.serving.router import Router

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.3, len(us)))
    classes = ["batch" if rng.random() < 0.3 else "interactive"
               for _ in us]
    tasks = _classed_sim_tasks(us, arrivals, classes)
    use_bulk = bulk and R > 1
    router = Router(R, rpolicy,
                    bulk_replicas=(R - 1,) if use_bulk else (),
                    bulk_classes=("batch",) if use_bulk else ())
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    pol = sched.POLICIES["rt-lm"](PERSONA, pcfg)
    res = simulator.simulate_replicated(
        tasks, pol, R=R, router=router, num_slots=4,
        kv_block_size=4, kv_num_blocks=64, prompt_len=8)
    assert len(res.placements) == len(us)
    assert sum(res.placement_counts()) == len(us)
    done_ids = sorted(t.task.task_id for rep in res.replicas
                      for t in rep.tasks)
    assert done_ids == list(range(len(us)))       # conservation
    for i, r in enumerate(res.placements):
        assert r in router.eligible(classes[i])
        if use_bulk:
            assert (r == R - 1) == (classes[i] == "batch")


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 48),
    R=st.integers(1, 5),
    seed=st.integers(0, 10),
)
def test_replicated_work_conservation_least_queue(n, R, seed):
    """All-at-t0 arrivals under least_queue: placements balance to
    within one request (round-robin by construction of the tie-break),
    every task completes exactly once, and the pool-level percentiles
    are ordered."""
    from repro.serving.router import Router

    rng = np.random.default_rng(seed)
    us = rng.uniform(0.5, 20.0, size=n)
    tasks = _classed_sim_tasks(us, [0.0] * n, [""] * n)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    pol = sched.POLICIES["fifo"](PERSONA, pcfg)
    res = simulator.simulate_replicated(
        tasks, pol, R=R, router=Router(R, "least_queue"),
        num_slots=4, kv_block_size=4, kv_num_blocks=64, prompt_len=8)
    counts = res.placement_counts()
    assert sum(counts) == n
    assert max(counts) - min(counts) <= 1         # work conservation
    done_ids = sorted(t.task.task_id for rep in res.replicas
                      for t in rep.tasks)
    assert done_ids == list(range(n))
    assert res.ttft_p50 <= res.ttft_p99 + 1e-9
    assert res.queue_wait_p50 <= res.queue_wait_p99 + 1e-9
