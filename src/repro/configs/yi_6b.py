"""Yi-6B — llama-architecture GQA model [arXiv:2403.04652].

Assignment row: [dense] 32L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    vocab_size=64000,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    mlp_act="swiglu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    source="arXiv:2403.04652 (Yi: Open Foundation Models by 01.AI)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense", num_layers=2, d_model=256,
        vocab_size=2048, num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        mlp_act="swiglu", tie_embeddings=False, source=CONFIG.source)
