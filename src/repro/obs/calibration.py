"""Uncertainty-predictor calibration ledger.

RT-LM schedules on u = m_theta(RULEGEN(J)), the predicted output
length.  The ledger measures, online, how good that prediction is: at
each ``complete`` event the caller records ``(u, realized output
length, realized latency)`` and the ledger maintains

  * streaming MAE / signed bias of ``u - out_len``,
  * per-u-bucket reliability rows (power-of-two u buckets, each with a
    predicted and a realized ``Histogram`` — the reliability-diagram
    substrate: predicted quantile vs realized quantile per bucket),
  * a windowed drift score: total-variation distance between the
    recent ``|error|`` distribution and a baseline frozen after the
    first ``baseline_n`` completions, over the existing log-bucket
    representation.

Drift windows are COUNT-based (epoch = completions // drift_window),
not time-based: the engine and the simulator complete the same
requests in the same order in the parity tests, so every quantity here
except the realized-latency histogram is bit-for-bit deterministic —
``parity()`` is the engine-vs-sim comparison view.  Latency (wall) is
kept in a separate histogram that never feeds the drift score.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

from .metrics import Histogram

#: dict key for the zero/non-positive bucket in drift distributions
_ZERO = "zero"


def u_bucket(u: float) -> int:
    """Power-of-two bucket index for a predicted length: ``-1`` for
    ``u < 1``, else ``floor(log2(u))`` (bucket ``k`` covers
    ``[2**k, 2**(k+1))``)."""
    if u < 1.0:
        return -1
    return int(math.floor(math.log2(u)))


class _Row:
    """One u bucket's reliability state."""

    __slots__ = ("n", "u_sum", "real_sum", "pred", "real")

    def __init__(self, growth: float) -> None:
        self.n = 0
        self.u_sum = 0.0
        self.real_sum = 0.0
        self.pred = Histogram(growth)
        self.real = Histogram(growth)


class CalibrationLedger:
    """Streaming u-vs-realized calibration state (see module doc)."""

    def __init__(self, *, growth: float = Histogram.GROWTH,
                 drift_window: int = 64, drift_windows: int = 4,
                 baseline_n: Optional[int] = None) -> None:
        if drift_window < 1:
            raise ValueError(f"drift_window must be >= 1, "
                             f"got {drift_window}")
        if drift_windows < 1:
            raise ValueError(f"drift_windows must be >= 1, "
                             f"got {drift_windows}")
        self.growth = float(growth)
        self.drift_window = int(drift_window)
        self.drift_windows = int(drift_windows)
        self.baseline_n = int(baseline_n if baseline_n is not None
                              else drift_window)
        self.count = 0
        self.err_sum = 0.0
        self.abs_err_sum = 0.0
        self.rows: Dict[int, _Row] = {}
        #: count-epoch -> |error| histogram (the recent-window ring)
        self._err_windows: Dict[int, Histogram] = {}
        #: |error| histogram frozen once ``count == baseline_n``
        self.baseline = Histogram(growth)
        self.baseline_frozen = False
        #: realized latency — wall-only, excluded from drift and parity
        self.latency = Histogram(growth)

    # ------------------------------------------------------------------
    def record(self, u: float, out_len: int,
               latency_s: Optional[float] = None) -> None:
        """Record one completion's prediction vs realization."""
        u = float(u)
        out_len = int(out_len)
        err = u - out_len
        epoch = self.count // self.drift_window
        self.count += 1
        self.err_sum += err
        self.abs_err_sum += abs(err)

        row = self.rows.get(u_bucket(u))
        if row is None:
            row = self.rows[u_bucket(u)] = _Row(self.growth)
        row.n += 1
        row.u_sum += u
        row.real_sum += float(out_len)
        row.pred.record(u)
        row.real.record(float(out_len))

        h = self._err_windows.get(epoch)
        if h is None:
            h = self._err_windows[epoch] = Histogram(self.growth)
            floor_epoch = epoch - self.drift_windows + 1
            for k in [k for k in self._err_windows if k < floor_epoch]:
                del self._err_windows[k]
        h.record(abs(err))
        if not self.baseline_frozen:
            self.baseline.record(abs(err))
            if self.count >= self.baseline_n:
                self.baseline_frozen = True

        if latency_s is not None:
            self.latency.record(float(latency_s))

    # ------------------------------------------------------------------
    @property
    def mae(self) -> float:
        return self.abs_err_sum / self.count if self.count else 0.0

    @property
    def bias(self) -> float:
        return self.err_sum / self.count if self.count else 0.0

    # ------------------------------------------------------------------
    @staticmethod
    def _dist(h: Histogram) -> Dict:
        if h.count == 0:
            return {}
        out = {k: n / h.count for k, n in h.buckets.items()}
        if h.zero_count:
            out[_ZERO] = h.zero_count / h.count
        return out

    def _recent(self) -> Histogram:
        h = Histogram(self.growth)
        for k in sorted(self._err_windows):
            h.merge(self._err_windows[k])
        return h

    def drift(self) -> float:
        """Total-variation distance in [0, 1] between the recent
        ``|error|`` distribution and the frozen baseline; 0.0 until the
        baseline is frozen (count-deterministic, hence parity-safe)."""
        if not self.baseline_frozen:
            return 0.0
        p = self._dist(self._recent())
        q = self._dist(self.baseline)
        if not p or not q:
            return 0.0
        keys = set(p) | set(q)
        return 0.5 * sum(abs(p.get(k, 0.0) - q.get(k, 0.0))
                         for k in keys)

    # ------------------------------------------------------------------
    def reliability(self) -> List[Dict]:
        """Per-u-bucket rows, ascending by bucket — the reliability
        diagram's data (predicted vs realized central quantiles)."""
        out: List[Dict] = []
        for k in sorted(self.rows):
            row = self.rows[k]
            out.append({
                "u_lo": 0.0 if k < 0 else float(2 ** k),
                "u_hi": 1.0 if k < 0 else float(2 ** (k + 1)),
                "n": row.n,
                "u_mean": row.u_sum / row.n,
                "u_p50": row.pred.quantile(0.5),
                "real_mean": row.real_sum / row.n,
                "real_p50": row.real.quantile(0.5),
                "real_p90": row.real.quantile(0.9),
            })
        return out

    def summary(self) -> Dict:
        """The ``_result``/``SimResult``-facing view."""
        return {"count": self.count, "mae": self.mae, "bias": self.bias,
                "drift": self.drift(),
                "reliability": self.reliability(),
                "latency": self.latency.snapshot()}

    def parity(self) -> Dict:
        """Deterministic engine-vs-sim comparison view (no latency)."""
        return {"count": self.count, "err_sum": self.err_sum,
                "abs_err_sum": self.abs_err_sum, "drift": self.drift(),
                "bucket_counts": {k: r.n
                                  for k, r in sorted(self.rows.items())}}
