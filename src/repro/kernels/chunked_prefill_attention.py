"""Chunked-prefill attention: a T-token chunk over a paged KV prefix.

The chunked-prefill engine (serving/engine.py ``prefill="chunked"``)
splits each admitted prompt into chunks and runs them inside the decode
loop; a chunk's queries must attend FULLY over the already-written
paged context (positions ``0 .. ctx_len-1``) and CAUSALLY within the
in-flight chunk (query ``t`` sees positions ``<= ctx_len + t``).  The
chunk's own K/V are scattered into the page pool *before* this kernel
runs (``kvcache.paged.scatter_chunk``), so the whole problem is one
masked attention over the block table — the same indirection as
``paged_decode_attention`` with a (T, G) query tile instead of (1, G).

Structure mirrors ``paged_decode_attention.py``: the block table rides
in as a scalar-prefetch operand (``PrefetchScalarGridSpec``) and the
innermost sequential grid dimension walks a sequence's logical blocks
while the BlockSpec index_map DMAs the *physical* page
``tables[b, i]`` into VMEM — no ``(B, max_len)`` contiguous view is
ever materialized (the pure-jnp oracle in ``kernels/ref.py``
materializes exactly that view; it is the semantic reference and the
CPU fallback path).

  grid = (B, KV, nb) — innermost sequential over table entries;
  per step: q tile (T*G, D) x page (block_size, D) on the MXU, masked
  by ``logical_pos <= ctx_len[b] + t`` (t = query row // G; padding
  table entries resolve to fully masked pages); running (m, l, acc)
  scratch identical to the decode kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _cp_kernel(tables_ref, clens_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr, *, scale: float, block_size: int,
               groups: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (T*G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, D) — page tables[b,ki]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (T*G, bs)
    # query row t*G + g sits at logical position ctx_len + t; this table
    # entry covers logical positions ki*bs .. ki*bs + bs - 1.  Causal
    # within the chunk, full over the prefix, padding entries all-masked.
    kv_pos = (ki * block_size
              + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    q_off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups
    valid = kv_pos <= clens_ref[b] + q_off
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # re-mask after the shift (see paged_decode_attention: an all-masked
    # row would otherwise average garbage page contents)
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def chunked_prefill_attention(q, k_pages, v_pages, block_tables,
                              ctx_lens, *, interpret: bool = False):
    """q: (B, T, H, D) chunk queries; pages: (N, bs, KV, D);
    block_tables: (B, nb) i32 physical page ids (pad with any valid
    id); ctx_lens: (B,) i32 prior-context lengths — the pages must
    already hold each row's chunk K/V at logical positions
    ``ctx_lens[b] .. ctx_lens[b] + T - 1``.  Returns (B, T, H, D).

    ``ctx_lens[b] == 0`` is the first-chunk edge: pure causal attention
    within the chunk (query 0 sees exactly one position).
    """
    B, T, H, D = q.shape
    N, bs, KV, _ = k_pages.shape
    _, nb = block_tables.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)

    # row layout t-major: row = t * G + g, so row // G recovers t
    qt = (q.reshape(B, T, KV, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, KV, T * G, D))
    kt = k_pages.transpose(2, 0, 1, 3)           # (KV, N, bs, D)
    vt = v_pages.transpose(2, 0, 1, 3)
    tables = block_tables.astype(jnp.int32)
    clens = ctx_lens.astype(jnp.int32)

    kernel = functools.partial(_cp_kernel, scale=scale, block_size=bs,
                               groups=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_tables, ctx_lens
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, T * G, D),
                         lambda b, h, i, t, c: (b, h, 0, 0)),
            # the indirection: page tables[b, i] streams into VMEM
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, t, c: (h, t[b, i], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, i, t, c: (h, t[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T * G, D),
                               lambda b, h, i, t, c: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, T * G, D), q.dtype),
        interpret=interpret,
    )(tables, clens, qt, kt, vt)
    return (out.reshape(B, KV, T, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, T, H, D))
