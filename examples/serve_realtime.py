"""End-to-end driver: serve a small model with batched requests (real JAX
execution, not the simulator).

    PYTHONPATH=src python examples/serve_realtime.py [--arch yi-6b]

The reduced (smoke) variant of an assigned architecture is served under
FIFO and RT-LM; requests arrive on a Poisson trace; batches run real
prefill + greedy decode through the engine.
"""

import argparse

import jax

from repro import configs
from repro.core import datagen, personas, scheduler, workload
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="yi-6b", choices=configs.ARCH_IDS)
ap.add_argument("--n", type=int, default=200)
args = ap.parse_args()

cfg = configs.get_smoke_config(args.arch)
print(f"loading {cfg.name} ...")
params = model_lib.init_params(jax.random.PRNGKey(0), cfg)

persona = personas.get_persona("dialogpt")
corpus = datagen.generate_corpus(datagen.VARIANCE_MIXES["large"],
                                 args.n * 2, seed=0)
train, test = datagen.train_test_split(corpus, train_frac=0.5)
test = test[:args.n]
profile = scheduler.offline_profile(train, persona, epochs=30)
arrivals = workload.poisson_trace(len(test), betas=[150, 300], seed=1)
requests = [Request(text=t.text, arrival=a, task_id=i)
            for i, (t, a) in enumerate(zip(test, arrivals))]

for policy_name in ("fifo", "rt-lm"):
    policy = scheduler.POLICIES[policy_name](persona,
                                             profile.policy_config())
    engine = ServingEngine(params, cfg, policy, profile,
                           input_bucket=32, max_new_tokens=16)
    res = engine.serve([Request(r.text, r.arrival, r.task_id)
                        for r in requests])
    print(f"{policy_name:6s} mean={res['mean_response_s']:.2f}s "
          f"max={res['max_response_s']:.2f}s "
          f"thr={res['throughput_per_min']:.0f}/min "
          f"sched_overhead={1000*res['scheduler_overhead_s']/res['n_tasks']:.2f}ms/task")
